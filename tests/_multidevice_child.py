"""Child process for test_multidevice.py (8 host devices)."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_arch
from repro.core import halo
from repro.core import migration as mig
from repro.models import moe as moe_lib
from repro.models.model import LanguageModel, init_params
from repro.sharding import MeshPlan, host_mesh, make_plan, single_device_plan

RESULTS = {}


def close(a, b, atol=3e-3):
    return bool(
        np.allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol
        )
    )


def check_halo():
    mesh = host_mesh((1, 8, 1), ("data", "ep", "tp"))
    plan = MeshPlan(mesh=mesh, ep=8, tp=1, dp_axes=("data",))
    R, d = 3, 5
    xg = jax.random.normal(jax.random.PRNGKey(0), (64, R, d))

    def run(fn):
        return compat.shard_map(
            fn, mesh=mesh, in_specs=P("ep", None, None),
            out_specs=P("ep", None, None), check_vma=False,
        )(xg)

    flat = run(halo.flat_all_to_all)
    for g1 in (2, 4):
        h = run(lambda xl, g=g1: halo.hierarchical_all_to_all(xl, plan, g1=g))
        RESULTS[f"halo_g1_{g1}"] = close(flat, h, atol=1e-6)


def check_pipeline_and_train():
    arch = get_arch("granite-moe-3b-a800m").reduced()
    arch = arch.replace(
        moe=dataclasses.replace(arch.moe, capacity_factor=8.0,
                                aux_loss_coef=0.0)
    )
    mesh = host_mesh((2, 2, 2), ("pod", "data", "model"))
    plan_pp = make_plan(mesh, arch, pipeline_on_pod=True)
    plan_dp = make_plan(mesh, arch)
    params = init_params(arch, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0,
                              arch.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    with plan_pp.mesh:
        lm_dp = LanguageModel(arch, plan_dp)
        lm_pp = LanguageModel(arch, plan_pp)
        l_dp, _ = jax.jit(lm_dp.loss)(params, batch)
        l_pp, _ = jax.jit(lm_pp.loss)(params, batch)
        RESULTS["pipeline_loss_match"] = close(l_dp, l_pp, atol=1e-4)
        g_dp = jax.jit(
            jax.grad(lambda p: lm_dp.loss(p, batch)[0], allow_int=True)
        )(params)
        g_pp = jax.jit(
            jax.grad(lambda p: lm_pp.loss(p, batch)[0], allow_int=True)
        )(params)
        g_dph = jax.tree.map(lambda t: np.asarray(jax.device_get(t)), g_dp)
        g_pph = jax.tree.map(lambda t: np.asarray(jax.device_get(t)), g_pp)
        # Embedding rows absorb near-tie top-k routing flips across token
        # layouts (see check_moe_ep below) — compare them in Frobenius norm,
        # everything else element-wise.
        emb_rel = np.linalg.norm(g_dph["embed"] - g_pph["embed"]) / (
            np.linalg.norm(g_dph["embed"]) + 1e-9
        )
        errs = jax.tree.map(
            lambda a, b: float(
                np.max(np.abs(a.astype(np.float32) - b.astype(np.float32)))
            )
            if np.issubdtype(a.dtype, np.floating)
            else 0.0,
            {k: v for k, v in g_dph.items() if k != "embed"},
            {k: v for k, v in g_pph.items() if k != "embed"},
        )
        RESULTS["pipeline_grad_match"] = max(jax.tree.leaves(errs)) < 1e-3
        RESULTS["pipeline_embed_grad_match"] = emb_rel < 0.05

        # compressed p2p: lossy but close
        plan_c = make_plan(mesh, arch, pipeline_on_pod=True)
        plan_c.compress_p2p = True
        lm_c = LanguageModel(arch, plan_c)
        l_c, _ = jax.jit(lm_c.loss)(params, batch)
        RESULTS["compressed_p2p_close"] = abs(float(l_c) - float(l_dp)) < 0.1


def check_moe_ep():
    arch = get_arch("granite-moe-3b-a800m").reduced()
    arch = arch.replace(
        moe=dataclasses.replace(arch.moe, capacity_factor=16.0)
    )
    params = init_params(arch, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0,
                              arch.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    plan1 = single_device_plan(arch)
    with plan1.mesh:
        lm1 = LanguageModel(arch, plan1)
        l1, _ = jax.jit(lm1.loss)(params, batch)
        g1 = jax.jit(jax.grad(lambda p: lm1.loss(p, batch)[0],
                              allow_int=True))(params)

    mesh = host_mesh((2, 4), ("data", "model"))
    plan8 = make_plan(mesh, arch)  # ep=4, tp=1 over the model axis
    with plan8.mesh:
        lm8 = LanguageModel(arch, plan8)
        l8, _ = jax.jit(lm8.loss)(params, batch)
        g8 = jax.jit(jax.grad(lambda p: lm8.loss(p, batch)[0],
                              allow_int=True))(params)
    # fp32 reduction-order noise across shardings is ~3e-4 on a 6.3 loss
    RESULTS["moe_ep_fwd_match"] = close(l1, l8, atol=2e-3)
    g1h = jax.tree.map(lambda t: np.asarray(jax.device_get(t)), g1)
    g8h = jax.tree.map(lambda t: np.asarray(jax.device_get(t)), g8)
    # Near-tie top-k routing can flip for a handful of tokens across
    # sharding layouts (fp32 reduction order in the router logits) — those
    # tokens' embedding rows then receive different (both-valid) expert
    # gradients.  Compare embeddings in Frobenius norm, everything else
    # element-wise.
    emb_rel = np.linalg.norm(g1h["embed"] - g8h["embed"]) / (
        np.linalg.norm(g1h["embed"]) + 1e-9
    )
    errs = jax.tree.map(
        lambda a, b: float(
            np.max(np.abs(a.astype(np.float32) - b.astype(np.float32)))
        )
        if np.issubdtype(a.dtype, np.floating)
        else 0.0,
        {k: v for k, v in g1h.items() if k != "embed"},
        {k: v for k, v in g8h.items() if k != "embed"},
    )
    RESULTS["moe_ep_grad_match"] = (
        max(jax.tree.leaves(errs)) < 2e-3 and emb_rel < 0.05
    )

    # end-to-end sharded train step matches the single-device loss
    from repro import training
    from repro.optim import OptimizerConfig

    opt = OptimizerConfig(lr=1e-3)
    with plan8.mesh:
        lm8 = LanguageModel(arch, plan8)
        state = training.init_state(lm8, jax.random.PRNGKey(0), opt)
        step = jax.jit(training.make_train_step(lm8, opt))
        state, metrics = step(state, batch)
    with plan1.mesh:
        lm1 = LanguageModel(arch, plan1)
        state1 = training.init_state(lm1, jax.random.PRNGKey(0), opt)
        step1 = jax.jit(training.make_train_step(lm1, opt))
        state1, metrics1 = step1(state1, batch)
    RESULTS["sharded_train_matches"] = (
        abs(float(metrics["loss"]) - float(metrics1["loss"])) < 1e-3
    )


def check_a2a_chunked():
    """Chunked double-buffered EP a2a == monolithic path, bit-for-bit on
    the loss and <= 1e-5 on every gradient, for both dispatch modes,
    K that does not divide the payload (tail chunk), and halo + chunks."""
    base = get_arch("granite-moe-3b-a800m").reduced()
    mesh = host_mesh((2, 4), ("data", "model"))
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0,
                              base.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    for mode in ("capacity", "ragged"):
        arch = base.replace(
            moe=dataclasses.replace(base.moe, dispatch=mode,
                                    capacity_factor=2.0)
        )
        params = init_params(arch, jax.random.PRNGKey(0))

        def loss_grad(plan):
            with plan.mesh:
                lm = LanguageModel(arch, plan)
                l, _ = jax.jit(lm.loss)(params, batch)
                g = jax.jit(jax.grad(lambda p: lm.loss(p, batch)[0],
                                     allow_int=True))(params)
            return float(l), jax.tree.map(
                lambda t: np.asarray(jax.device_get(t)), g
            )

        l0, g0 = loss_grad(make_plan(mesh, arch))  # monolithic K=1, flat
        # K=2 (even), K=3 (tail chunk: neither capacity nor the ragged
        # wire size divides by 3), and halo composed with chunking.
        for tag, halo_on, K in (("K2", False, 2), ("K3_tail", False, 3),
                                ("halo_K2", True, 2)):
            plan = make_plan(mesh, arch, hierarchical_a2a=halo_on,
                             a2a_chunks=K)
            l1, g1 = loss_grad(plan)
            dmax = max(jax.tree.leaves(jax.tree.map(
                lambda a, b: float(np.max(np.abs(
                    a.astype(np.float32) - b.astype(np.float32)
                ))) if np.issubdtype(a.dtype, np.floating) else 0.0,
                g0, g1,
            )))
            RESULTS[f"a2a_chunked_{mode}_{tag}"] = (
                abs(l1 - l0) < 1e-5 and dmax < 1e-5
            )


def check_replication():
    """Hot-expert replication is function-preserving: the SAME arch and
    params with live replica channels (replicated experts compute
    source-locally off the a2a wire; their weights psum-broadcast over the
    EP groups, grads summed back by the psum transpose) match the
    sentinel-table oracle to <= 1e-5 on loss and every gradient, per
    dispatch mode, on the real EP mesh.  The oracle must be the same arch
    with an INACTIVE table — dropping the replicas leaf instead would
    shift every init PRNG key and change all weights."""
    base = get_arch("granite-moe-3b-a800m").reduced()
    mesh = host_mesh((2, 4), ("data", "model"))
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0,
                              base.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    for mode in ("ragged", "capacity"):
        arch = base.replace(
            moe=dataclasses.replace(base.moe, dispatch=mode,
                                    capacity_factor=8.0, max_replicas=2)
        )
        params = init_params(arch, jax.random.PRNGKey(0))  # sentinel table

        def with_live_table(p):
            blocks = []
            for blk in p["blocks"]:
                if "ffn" in blk and "replicas" in blk["ffn"]:
                    f = dict(blk["ffn"])
                    reps = f["replicas"].shape[0]
                    f["replicas"] = jnp.tile(
                        jnp.asarray([0, 3], jnp.int32), (reps, 1)
                    )
                    blk = {**blk, "ffn": f}
                blocks.append(blk)
            return {**p, "blocks": tuple(blocks)}

        plan8 = make_plan(mesh, arch)
        lm8 = LanguageModel(arch, plan8)

        def loss_grad(p):
            with plan8.mesh:
                l, _ = jax.jit(lm8.loss)(p, batch)
                g = jax.jit(jax.grad(lambda q: lm8.loss(q, batch)[0],
                                     allow_int=True))(p)
            return float(l), jax.tree.map(
                lambda t: np.asarray(jax.device_get(t)), g
            )

        l0, g0 = loss_grad(params)
        l1, g1 = loss_grad(with_live_table(params))
        dmax = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.max(np.abs(
                a.astype(np.float32) - b.astype(np.float32)
            ))) if np.issubdtype(a.dtype, np.floating) else 0.0,
            g0, g1,
        )))
        RESULTS[f"replication_{mode}_train_parity"] = (
            abs(l1 - l0) < 1e-5 and dmax < 1e-5
        )

        # Decode path (replicated tokens, round-robin replica ownership +
        # psum): no wire cast, so exact parity.
        ffn = jax.tree.map(lambda t: t[0], params["blocks"][0]["ffn"])
        ffn_rep = dict(ffn, replicas=jnp.asarray([0, 3], jnp.int32))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, arch.d_model))
        with plan8.mesh:
            y0, _ = jax.jit(lambda f, xx: moe_lib.moe_ffn(
                f, xx, arch, plan8, token_sharded=False))(ffn, x)
            y1, _ = jax.jit(lambda f, xx: moe_lib.moe_ffn(
                f, xx, arch, plan8, token_sharded=False))(ffn_rep, x)
        RESULTS[f"replication_{mode}_decode_parity"] = bool(
            np.max(np.abs(np.asarray(y0) - np.asarray(y1))) < 1e-5
        )


def check_migration_exactness():
    """The trainer's migration at step k is exactly ONE permutation pass:
    params and both Adam moment trees move with identical perms (bit-equal
    to a manual application — the dead-counter/recomputed-perms bug class),
    the jitted step does not recompile on the migrated state, and the loss
    trajectory is bit-identical to a run whose INIT carried the same
    permutation from step 0 (slot relabeling is bit-invariant).  Swap-only
    arch (max_replicas=0): activating replica channels changes the
    reduction route and is only 1e-5-close, never bit-equal — that path is
    pinned by check_replication instead."""
    from repro import training
    from repro.optim import OptimizerConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    base = get_arch("granite-moe-3b-a800m").reduced()
    arch = base.replace(
        moe=dataclasses.replace(base.moe, capacity_factor=8.0,
                                aux_loss_coef=0.0)
    )
    mesh = host_mesh((2, 4), ("data", "model"))
    plan8 = make_plan(mesh, arch)
    lm8 = LanguageModel(arch, plan8)
    opt = OptimizerConfig(lr=1e-3)
    moe_positions = [
        i for i, (_, f) in enumerate(arch.block_pattern) if f == "moe"
    ]

    def batch_at(s):
        rng = np.random.default_rng(s)
        toks = rng.integers(0, 4, size=(8, 32), dtype=np.int32)  # skewed
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}

    def feed_loads(tr, met):
        loads = np.asarray(jax.device_get(met["expert_load"]))
        tr.load_stats.update(
            np.concatenate([loads[:, i, :] for i in range(loads.shape[1])])
        )

    k, n = 3, 6
    cfg = TrainerConfig(migrate_every=1, migrate_threshold=1.05)
    tr = Trainer(lm8, opt, cfg, log_fn=lambda s: None)
    with plan8.mesh:
        state = training.init_state(lm8, jax.random.PRNGKey(0), opt)
    losses_a = []
    perms_by_pos = {}
    tables_by_pos = {}
    for s in range(n):
        with plan8.mesh:
            state, met = tr.train_step(state, batch_at(s))
        losses_a.append(float(jax.device_get(met["loss"])))
        feed_loads(tr, met)
        if s == k - 1:
            cache_pre = tr.train_step._cache_size()
            old_state_host = jax.tree.map(
                lambda t: np.asarray(jax.device_get(t)), state
            )
            state = tr._maybe_migrate(state, 1)
            RESULTS["migration_applied"] = bool(
                tr.migrations and tr.migrations[-1]["applied"]
            )
            # Capture what the controller did and replay it by hand on the
            # pre-migration host copy: params AND m AND v must match the
            # controller's output bit-for-bit.
            exact = True
            for pos in moe_positions:
                old_a = old_state_host["params"]["blocks"][pos]["ffn"]["assignment"]
                new_a = np.asarray(
                    state["params"]["blocks"][pos]["ffn"]["assignment"]
                )
                perms = np.stack([
                    mig.permutation_for(old_a[r], new_a[r])
                    for r in range(old_a.shape[0])
                ])
                perms_by_pos[pos] = perms
                tables_by_pos[pos] = {"assignment": new_a}
                for tree in ("params", "m", "v"):
                    want = mig.apply_migration_to_tree(
                        dict(old_state_host[tree]["blocks"][pos]["ffn"]),
                        perms,
                    )
                    got = state[tree]["blocks"][pos]["ffn"]
                    for key in mig.EXPERT_PARAM_KEYS:
                        if key not in want:
                            continue
                        exact &= bool(np.array_equal(
                            np.asarray(want[key]),
                            np.asarray(jax.device_get(got[key])),
                        ))
            RESULTS["migration_moments_exact"] = exact
    RESULTS["migration_no_recompile"] = (
        tr.train_step._cache_size() == cache_pre
    )

    # Run B: the captured permutation baked in at init, no migration.
    with plan8.mesh:
        state_b = training.init_state(lm8, jax.random.PRNGKey(0), opt)
    blocks = {t: list(state_b[t]["blocks"]) for t in ("params", "m", "v")}
    for pos, perms in perms_by_pos.items():
        for t in ("params", "m", "v"):
            blk = dict(blocks[t][pos])
            blk["ffn"] = mig.apply_migration_to_tree(dict(blk["ffn"]), perms)
            if t == "params":
                blk["ffn"]["assignment"] = jnp.asarray(
                    tables_by_pos[pos]["assignment"]
                )
            blocks[t][pos] = blk
    state_b = {
        **state_b,
        **{t: {**state_b[t], "blocks": tuple(blocks[t])}
           for t in ("params", "m", "v")},
    }
    tr_b = Trainer(lm8, opt, TrainerConfig(migrate_every=10**9),
                   log_fn=lambda s: None)
    losses_b = []
    for s in range(n):
        with plan8.mesh:
            state_b, met = tr_b.train_step(state_b, batch_at(s))
        losses_b.append(float(jax.device_get(met["loss"])))
    RESULTS["migration_trajectory_bitexact"] = losses_a == losses_b


if __name__ == "__main__":
    assert len(jax.devices()) == 8, jax.devices()
    check_halo()
    check_pipeline_and_train()
    check_moe_ep()
    check_a2a_chunked()
    check_replication()
    check_migration_exactness()
    print("RESULTS " + json.dumps({k: bool(v) for k, v in RESULTS.items()}))
