"""Expert migration: Algorithm 2 properties + function preservation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Only the property tests need the hypothesis dev extra — everything else
# in this file must still run without it.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import migration as mig

if HAVE_HYPOTHESIS:
    _property = lambda f: settings(deadline=None, max_examples=40)(
        given(
            E=st.integers(4, 32),
            ep=st.sampled_from([2, 4]),
            seed=st.integers(0, 2**16),
        )(f)
    )
else:
    _property = pytest.mark.skip(reason="hypothesis not installed")


@_property
def test_hill_climb_reduces_imbalance(E=8, ep=2, seed=0):
    E = (E // ep) * ep
    if E < ep:
        return
    rng = np.random.default_rng(seed)
    loads = rng.exponential(1.0, E)
    assignment = np.arange(E, dtype=np.int32)

    def gap(assign):
        e_l = E // ep
        sums = np.zeros(ep)
        np.add.at(sums, assign // e_l, loads)
        return sums.max() - sums.min()

    new_assign, swaps = mig.rebalance_assignment(loads, assignment, ep)
    assert gap(new_assign) <= gap(assignment) + 1e-9
    # group sizes preserved
    e_l = E // ep
    for g in range(ep):
        assert (new_assign // e_l == g).sum() == e_l
    # it is a permutation
    assert sorted(new_assign.tolist()) == list(range(E))


def test_hill_climb_terminates_and_counts():
    loads = np.array([10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 10.0])
    groups = [[(0, 10.0), (7, 10.0)], [(1, 1.0), (2, 1.0)],
              [(3, 1.0), (4, 1.0)], [(5, 1.0), (6, 1.0)]]
    new_groups, swaps = mig.hill_climb_rebalance(groups, max_iters=100)
    sums = [sum(l for _, l in g) for g in new_groups]
    assert max(sums) - min(sums) < 20.0
    assert 0 < swaps <= 100


def test_permutation_roundtrip():
    rng = np.random.default_rng(0)
    E = 12
    old = np.arange(E, dtype=np.int32)
    new = rng.permutation(E).astype(np.int32)
    perm = mig.permutation_for(old, new)
    # W_new[s] = W_old[perm[s]]; logical expert e must end at new[e]
    W_old = rng.normal(size=(E, 3))
    W_new = W_old[perm]
    for e in range(E):
        np.testing.assert_allclose(W_new[new[e]], W_old[old[e]])


def test_migration_preserves_model_function():
    from repro.configs import get_arch
    from repro.models.model import LanguageModel, init_params
    from repro.sharding import single_device_plan

    arch = get_arch("granite-moe-3b-a800m").reduced()
    arch = arch.replace(
        moe=dataclasses.replace(arch.moe, capacity_factor=16.0)
    )
    plan = single_device_plan(arch)
    with plan.mesh:
        lm = LanguageModel(arch, plan)
        params = init_params(arch, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  arch.vocab_size)
        batch = {"tokens": toks}
        logits0, _, _ = jax.jit(lm.forward)(params, batch)

        rng = np.random.default_rng(0)
        E = arch.moe.num_experts
        ffn = params["blocks"][0]["ffn"]
        old = np.asarray(ffn["assignment"])
        reps = old.shape[0]
        new = np.stack([rng.permutation(E) for _ in range(reps)]).astype(np.int32)
        perms = np.stack(
            [mig.permutation_for(old[r], new[r]) for r in range(reps)]
        )
        new_ffn = mig.apply_migration_to_tree(ffn, perms)
        new_ffn["assignment"] = jnp.asarray(new)
        blocks = list(params["blocks"])
        blk = dict(blocks[0])
        blk["ffn"] = new_ffn
        blocks[0] = blk
        params2 = {**params, "blocks": tuple(blocks)}
        logits1, _, _ = jax.jit(lm.forward)(params2, batch)
        np.testing.assert_allclose(
            np.asarray(logits0), np.asarray(logits1), atol=1e-4
        )


def test_load_stats_and_trigger():
    stats = mig.LoadStats(num_layers=2, num_experts=8, decay=0.5)
    skewed = np.zeros((2, 8))
    skewed[:, 0] = 100.0
    skewed[:, 1:] = 1.0
    for _ in range(5):
        stats.update(skewed)
    assign = np.tile(np.arange(8, dtype=np.int32), (2, 1))
    imb = stats.imbalance(assign, ep=4)
    assert imb > 1.5
    balanced = np.ones((2, 8))
    stats2 = mig.LoadStats(2, 8)
    stats2.update(balanced)
    assert stats2.imbalance(assign, ep=4) == pytest.approx(1.0, abs=1e-6)


def test_migration_cost_matches_paper_table4():
    """Table IV rows: Mixtral 8x7B -> 2.63 GB, 52.6 ms; DeepSeek-V3 ->
    21 GB, 420 ms.  (The paper's 'GB' column is GiB — 48*8*4096*14336/8
    = 2.818e9 B = 2.625 GiB — and its latency divides that GiB number by
    50, so we compare in the paper's own convention.)"""
    GIB = 2**30
    size, _ = mig.migration_cost(E=8, d_model=4096, d_ffn=14336)
    assert abs(size / GIB - 2.63) < 0.05
    assert abs(size / GIB / 50 * 1e3 - 52.6) < 1.0
    size, _ = mig.migration_cost(E=256, d_model=7168, d_ffn=2048)
    assert abs(size / GIB - 21.0) < 0.1
    assert abs(size / GIB / 50 * 1e3 - 420.0) < 2.0


# ---------------------------------------------------------------------------
# Swap-only blind spot, replication planner, and LoadStats persistence
# ---------------------------------------------------------------------------


def _layer_imbalance(loads, assignment, ep, replicas=None):
    ls = mig.LoadStats(1, len(loads))
    ls.ema[0] = np.asarray(loads, dtype=np.float64)
    reps = None if replicas is None else np.asarray(replicas)[None, :]
    return ls.imbalance(np.asarray(assignment)[None, :], ep, reps)


def test_plan_layer_noop_on_balanced():
    E, ep = 8, 4
    loads = np.full(E, 10.0)
    assign = np.arange(E, dtype=np.int32)
    reps = np.full(2, E, dtype=np.int32)  # all channels free
    new_a, new_r, perm, swaps = mig.plan_layer(loads, assign, reps, ep)
    assert swaps == 0
    assert np.array_equal(new_a, assign)
    assert np.array_equal(perm, np.arange(E))
    assert np.array_equal(new_r, reps)  # no channel engages on balance


def test_plan_layer_converges_on_mild_skew():
    """No expert exceeds fair share -> swaps alone reach near-perfect
    balance (the regime Algorithm 2 is built for)."""
    ep = 4
    loads = np.array([30, 25, 10, 15, 22, 18, 28, 12.0])
    assign = np.arange(8, dtype=np.int32)
    pre = _layer_imbalance(loads, assign, ep)
    new_a, new_r, _, swaps = mig.plan_layer(loads, assign, None, ep)
    post = _layer_imbalance(loads, new_a, ep)
    assert new_r is None
    assert swaps > 0
    assert post < pre
    assert post <= 1.15  # near the floor of 1.0
    assert mig.swap_floor(loads, ep) == 1.0


def test_swap_only_cannot_beat_dominant_expert_floor():
    """One expert above a group's fair share: swap-only bottoms out at
    max(load_e)/fair_share (the tentpole's motivating bug), while one
    replica channel splits the hot expert's load and beats that floor."""
    ep = 4
    loads = np.array([100, 5, 5, 5, 5, 5, 5, 5.0])
    assign = np.arange(8, dtype=np.int32)
    floor = mig.swap_floor(loads, ep)
    assert floor > 2.5  # 100 / (135/4)

    new_a, _, _, _ = mig.plan_layer(loads, assign, None, ep)
    assert _layer_imbalance(loads, new_a, ep) >= floor - 1e-9

    reps = np.full(2, 8, dtype=np.int32)
    rep_a, rep_r, _, _ = mig.plan_layer(loads, assign, reps, ep)
    assert (rep_r < 8).sum() >= 1  # hot expert got a channel
    assert _layer_imbalance(loads, rep_a, ep, rep_r) < floor


def test_plan_replication_hysteresis():
    """Channels engage above fair share, are HELD in the cool-down band
    (no flapping), and release only below release_factor * fair."""
    E, ep = 8, 4
    free = np.full(2, E, dtype=np.int32)
    hot = np.array([100, 5, 5, 5, 5, 5, 5, 5.0])
    held = mig.plan_replication(hot, free, ep)
    assert 0 in held

    # 8.75 < 10 < 11.67: below the acquire threshold, above release.
    warm = np.array([10, 5, 5, 5, 5, 5, 5, 5.0])
    assert 0 in mig.plan_replication(warm, held, ep)  # held channel stays
    assert 0 not in mig.plan_replication(warm, free, ep)  # no new acquire

    cold = np.full(E, 5.0)
    released = mig.plan_replication(cold, held, ep)
    assert np.all(released == E)


def test_load_stats_state_roundtrip_bit_exact():
    rng = np.random.default_rng(0)
    ls = mig.LoadStats(3, 8, decay=0.85)
    for _ in range(5):
        ls.update(rng.integers(0, 100, size=(3, 8)))
    state = ls.to_state()
    ls2 = mig.LoadStats.from_state(state)
    assert ls.ema.tobytes() == ls2.ema.tobytes()  # bit-exact, not approx
    assert (ls2.steps, ls2.decay) == (ls.steps, ls.decay)

    with pytest.raises(ValueError):
        mig.LoadStats(2, 8).load_state(state)
