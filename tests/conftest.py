"""Shared fixtures.  NOTE: device count must stay 1 here (smoke tests /
benches see the real host); multi-device tests live in test_multidevice.py
which re-executes itself in a subprocess with XLA_FLAGS set."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.sharding import single_device_plan


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(arch, b=2, s=32, key=None):
    key = key if key is not None else jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (b, s), 0, arch.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if arch.frontend:
        batch["embeds"] = jax.random.normal(
            key, (b, s, arch.d_model), jnp.float32
        )
    return batch
