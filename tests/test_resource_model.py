"""Resource model (paper Eq 1-6, 12) and planner (Eq 7-11) tests."""

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import planner, resource_model as rm, schedule_sim as ss
from repro.core.platform import FRONTIER, TPU_V5E


def _setup(**kw):
    base = dict(b=256, s=4096)
    base.update(kw)
    return rm.TrainSetup(**base)


def test_memory_eq1_vs_eq2_consistency():
    """EP=1, DP=1 EDP memory equals the unpartitioned bound (same policy)."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(EP=1, DP=1, zero="none", framework_overhead=0.0)
    mu = rm.memory_unpartitioned(m, t)
    medp = rm.memory_edp(m, t)
    # memory_edp includes embeddings which Eq 1 (layer-only) omits
    embed = t.bytes_per_param * 2 * m.vocab * m.d_model
    assert medp == pytest.approx(mu + embed, rel=0.01)


def test_memory_monotone_in_ep():
    m = rm.ModelShape.from_arch(get_arch("piper-super-545b"))
    t8 = _setup(EP=8, zero="none")
    t32 = _setup(EP=32, zero="none")
    assert rm.memory_edp(m, t32) < rm.memory_edp(m, t8)


def test_1f1b_stage_skew_eq5():
    """Eq 5: stage-0 holds (PP-1)x more in-flight activation than the last;
    the skew equals the closed form."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t = _setup(PP=4, EP=16, alpha=2, zero="none")
    skew = rm.memory_1f1b_skew(m, t)
    m0 = rm.memory_pp_1f1b(m, t, 0)
    mlast = rm.memory_pp_1f1b(m, t, t.PP - 1)
    assert skew == pytest.approx(m0 - mlast)
    assert skew > 0


def test_1f1b_peak_matches_schedule_sim():
    """Paper Eq 4 peak in-flight microbatches == discrete-event simulation."""
    for PP, M in [(2, 4), (4, 8), (8, 16)]:
        sim = ss.one_f_one_b(PP, M)
        assert sim.peak_in_flight == ss.peak_activations_1f1b(PP)


def test_gpipe_holds_all_microbatches():
    sim = ss.gpipe(4, 8)
    assert sim.peak_in_flight == [8, 8, 8, 8]


def test_bubble_fraction():
    from repro.core.pipeline import bubble_fraction

    for PP, M in [(2, 4), (4, 8)]:
        sim = ss.one_f_one_b(PP, M, t_fwd=1.0, t_bwd=2.0)
        assert sim.bubble_fraction == pytest.approx(
            bubble_fraction(PP, M), abs=0.02
        )


def test_a2a_bound_eq6_scaling():
    """Eq 6: a2a time scales ~1/EP at fixed token count and grows with s."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t8 = _setup(EP=8)
    t16 = _setup(EP=16)
    b8 = rm.t_a2a_lower_bound(m, t8, FRONTIER)
    b16 = rm.t_a2a_lower_bound(m, t16, FRONTIER)
    assert b16 < b8
    t_long = _setup(EP=8, s=8192)
    assert rm.t_a2a_lower_bound(m, t_long, FRONTIER) > b8


def test_planner_constraints():
    """Every emitted strategy satisfies Eq 7-11."""
    arch = get_arch("piper-super-545b")
    strategies = planner.valid_strategies(
        arch, FRONTIER, 512, batch=256, seq=4096
    )
    assert strategies
    E = arch.moe.num_experts
    for s in strategies:
        assert s.PP * s.EP * s.DP == 512  # Eq 7
        assert E % s.EP == 0  # Eq 8
        assert s.PP <= arch.num_layers  # Eq 9
        assert s.EP <= FRONTIER.fast_domain  # Eq 10
        assert s.estimate.mem_ok  # Eq 11


def test_planner_mfu_in_paper_band():
    """Paper: SOTA MoE at 20-50% MFU on Frontier; X-MoE super at 5%."""
    best = planner.best_strategy(
        get_arch("piper-super-545b"), FRONTIER, 512, batch=256, seq=4096
    )
    assert best is not None
    assert 0.15 < best.estimate.mfu < 0.55


def test_planner_min_chips_fig10():
    """Fig 10: the 545B/615B-class model needs >= 64 nodes worth of HBM
    without ZeRO (paper trains it from 64 nodes = 512 GCDs)."""
    arch = get_arch("piper-super-545b")
    mc = planner.min_chips(
        arch, FRONTIER, batch=256, seq=4096,
        chip_counts=[8, 16, 32, 64, 128, 256, 512],
    )
    assert mc is not None and mc >= 64


def test_all_assigned_archs_plannable_on_v5e():
    from repro.configs import ASSIGNED

    for name in ASSIGNED:
        s = planner.best_strategy(
            get_arch(name), TPU_V5E, 256, batch=256, seq=4096, zero="world"
        )
        assert s is not None, name


def test_interleaved_memory_between_1f1b_and_double():
    """The interleaved Eq-4 analogue: more residual memory than plain 1F1B
    (deeper warmup), but the chunks are 1/V of a stage, so the activation
    term stays within ~2x of Eq 4."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t1 = _setup(PP=4, EP=16, alpha=2, zero="none", schedule="1f1b")
    t2 = _setup(PP=4, EP=16, alpha=2, zero="none",
                schedule="interleaved_1f1b", vstages=2)
    m1 = rm.memory_pp(m, t1, 0)
    m2 = rm.memory_pp(m, t2, 0)
    act1 = m1 - rm.static_state_bytes(m, t1, m.L / t1.PP) - t1.framework_overhead
    act2 = m2 - rm.static_state_bytes(m, t2, m.L / t2.PP) - t2.framework_overhead
    assert m1 < m2
    assert act2 < 2.0 * act1 + 1e-6
    # vstages=1 interleaving is plain 1F1B, in memory too
    t0 = _setup(PP=4, EP=16, alpha=2, zero="none",
                schedule="interleaved_1f1b", vstages=1)
    assert rm.memory_pp(m, t0, 0) == m1


def test_planner_ranks_interleaved_above_plain_1f1b():
    """Acceptance: for at least one assigned MoE arch, interleaved 1F1B is
    feasible (its V× residual memory still fits Eq 4/11) and outranks every
    plain 1f1b strategy — the lower Eq-3 bubble wins at equal partition."""
    from repro.configs import ASSIGNED

    won = []
    for name in ASSIGNED:
        arch = get_arch(name)
        if arch.moe is None or arch.num_layers < 4:
            continue
        ranked = planner.rank_strategies(
            planner.valid_strategies(
                arch, TPU_V5E, 256, batch=256, seq=4096, zero="world"
            )
        )
        il = [s for s in ranked if s.schedule == "interleaved_1f1b"]
        fl = [s for s in ranked if s.schedule == "1f1b" and s.PP > 1]
        if il and fl and ranked.index(il[0]) < ranked.index(fl[0]):
            best = il[0]
            assert best.vstages > 1
            assert best.estimate.mem_ok
            # against plain 1f1b of the SAME partition the win is exactly
            # the 1/V bubble (same compute, same collectives)
            same = [
                s for s in fl
                if (s.PP, s.EP, s.DP, s.alpha)
                == (best.PP, best.EP, best.DP, best.alpha)
            ]
            for s in same:
                assert best.estimate.bubble_fraction < s.estimate.bubble_fraction
            won.append(name)
    assert won, "no arch ranks interleaved above plain 1f1b"


def test_planner_vstages_are_executor_valid():
    """Regression: V candidates must divide the BLOCK-PATTERN reps per
    stage (the executor's chunk unit), not raw layers — on hybrid archs
    (pattern period > 1) the two differ and an invalid V crashes
    ``pipeline._stage_block_params``."""
    from repro.core.planner import _schedule_candidates

    for name in ("gemma2-9b", "jamba-1.5-large-398b", "granite-moe-3b-a800m"):
        arch = get_arch(name)
        reps = arch.num_layers // len(arch.block_pattern)
        for PP in (2, 3, 4, 8):
            for schedule, V in _schedule_candidates(arch, PP):
                if schedule != "interleaved_1f1b":
                    assert V == 1
                    continue
                assert V > 1 and reps % (PP * V) == 0, (name, PP, V, reps)


def test_interleaved_estimate_tradeoffs():
    """Same partition, V=2: smaller bubble, more p2p, more stage-0 memory."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    kw = dict(PP=4, EP=4, DP=16, alpha=2, zero="world")
    e1 = rm.estimate(m, _setup(schedule="1f1b", **kw), TPU_V5E)
    e2 = rm.estimate(
        m, _setup(schedule="interleaved_1f1b", vstages=2, **kw), TPU_V5E
    )
    assert e2.bubble_fraction == pytest.approx(e1.bubble_fraction / 2)
    assert e2.t_p2p == pytest.approx(2 * e1.t_p2p)
    assert e2.mem_stage0 > e1.mem_stage0


# ---------------------------------------------------------------------------
# Comm-lane pricing (1f1b_overlap)
# ---------------------------------------------------------------------------


def test_overlap_estimate_tradeoffs():
    """Same partition: 1f1b_overlap keeps 1f1b's compute, bubble and serial
    p2p reference, charges only the comm-lane replay's exposed p2p (plus
    the better of the two a2a hidings), pays the comm buffer in stage-0
    memory, and strictly wins the step."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    kw = dict(PP=4, EP=4, DP=16, alpha=4, zero="world")
    e1 = rm.estimate(m, _setup(schedule="1f1b", **kw), TPU_V5E)
    eo = rm.estimate(m, _setup(schedule="1f1b_overlap", **kw), TPU_V5E)
    assert eo.t_compute == e1.t_compute
    assert eo.bubble_fraction == e1.bubble_fraction
    assert eo.t_p2p == pytest.approx(e1.t_p2p)  # same Eq serial reference
    assert 0.0 < eo.t_p2p_exposed < e1.t_p2p_exposed
    assert eo.p2p_overlap_saving == pytest.approx(
        eo.t_p2p - eo.t_p2p_exposed
    )
    assert eo.t_a2a_exposed <= e1.t_a2a_exposed
    assert eo.comm_buf_bytes > 0 and e1.comm_buf_bytes == 0.0
    assert eo.mem_stage0 == pytest.approx(e1.mem_stage0 + eo.comm_buf_bytes)
    assert eo.t_step < e1.t_step
    assert eo.mfu > e1.mfu
    # legacy schedules keep the flat serial charge (t_step bit-identity)
    assert e1.t_p2p_exposed == e1.t_p2p and e1.p2p_overlap_saving == 0.0


def test_overlap_exposure_pinned_to_schedule_replay():
    """The model's exposed-comm terms ARE the schedule replay: recompute
    the per-op durations from the estimate's own serial references and the
    simulator must return the same exposure — no second accounting."""
    from repro.core.schedules import build

    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t = _setup(PP=4, EP=16, alpha=4, zero="none", schedule="1f1b_overlap")
    e = rm.estimate(m, t, FRONTIER)
    M = t.M
    r = ss.simulate(
        build("1f1b_overlap", t.PP, M),
        t_fwd=e.t_compute / (3.0 * M),
        t_bwd=2.0 * e.t_compute / (3.0 * M),
        t_p2p=e.t_p2p / (2.0 * M * t.vstages),
        t_a2a=e.t_a2a / (2.0 * M),
    )
    assert e.t_p2p_exposed == pytest.approx(r.exposed_p2p, rel=1e-12)
    # a2a takes the better of the chunk model and the bracket replay
    assert e.t_a2a_exposed <= r.exposed_a2a + 1e-12
    assert e.t_a2a_exposed <= e.t_a2a


def test_planner_enumerates_overlap():
    """1f1b_overlap is a first-class candidate wherever PP > 1 (V=1)."""
    from repro.core.planner import _schedule_candidates

    for name in ("granite-moe-3b-a800m", "piper-m10b-e16"):
        arch = get_arch(name)
        for PP in (2, 4, 8):
            cands = _schedule_candidates(arch, PP)
            assert ("1f1b_overlap", 1) in cands, (name, PP)


def test_planner_ranks_overlap_above_plain_1f1b():
    """Acceptance: for at least one assigned MoE arch the best
    1f1b_overlap strategy outranks the best plain 1f1b one — identical
    compute/bubble/memory partition (modulo the comm buffer), with the
    comm-lane replay's exposed p2p strictly below the serial charge."""
    from repro.configs import ASSIGNED

    won = []
    for name in ASSIGNED:
        arch = get_arch(name)
        if arch.moe is None or arch.num_layers < 4:
            continue
        ranked = planner.rank_strategies(
            planner.valid_strategies(
                arch, TPU_V5E, 256, batch=256, seq=4096, zero="world"
            )
        )
        ov = [s for s in ranked if s.schedule == "1f1b_overlap"]
        fl = [s for s in ranked if s.schedule == "1f1b" and s.PP > 1]
        if not (ov and fl):
            continue
        if ranked.index(ov[0]) < ranked.index(fl[0]):
            best = ov[0]
            assert best.estimate.mem_ok
            same = [
                s for s in fl
                if (s.PP, s.EP, s.DP, s.alpha)
                == (best.PP, best.EP, best.DP, best.alpha)
            ]
            for s in same:
                assert best.estimate.t_step <= s.estimate.t_step
                # the win is comm exposure: the comm-lane replay never
                # charges more TOTAL exposed comm than the serial
                # reference (per-channel the flat legacy charge is only a
                # lower bound of the synchronous replay, so p2p alone may
                # not shrink at M ~ PP — the sim-level strict-win test
                # compares like against like)
                assert (
                    best.estimate.t_p2p_exposed + best.estimate.t_a2a_exposed
                    <= s.estimate.t_p2p_exposed + s.estimate.t_a2a_exposed
                )
            won.append(name)
    assert won, "no arch ranks 1f1b_overlap above plain 1f1b"


# ---------------------------------------------------------------------------
# ZB-H1 pricing (the zero-bubble split backward)
# ---------------------------------------------------------------------------


def test_zb_h1_estimate_tradeoffs():
    """Same partition: zb_h1's bubble overhead is exactly a third of
    1f1b's ((PP-1)/(3M) vs (PP-1)/M — the t_F = t_Bi = t_Bw regime), p2p
    is unchanged (Bw never touches the wire), and the only memory delta is
    the W-stash term, reported separately and included in mem_stage0."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    kw = dict(PP=4, EP=4, DP=16, alpha=2, zero="world")
    e1 = rm.estimate(m, _setup(schedule="1f1b", **kw), TPU_V5E)
    ez = rm.estimate(m, _setup(schedule="zb_h1", **kw), TPU_V5E)
    assert ez.bubble_fraction == pytest.approx(e1.bubble_fraction / 3)
    assert ez.t_p2p == pytest.approx(e1.t_p2p)
    assert ez.wstash_bytes > 0 and e1.wstash_bytes == 0
    assert ez.mem_stage0 == pytest.approx(e1.mem_stage0 + ez.wstash_bytes)
    assert ez.mfu > e1.mfu  # same work, smaller bubble


def test_zb_h1_wstash_bytes_formula():
    """The W-stash term: min(PP, M) slots x two (b_mu, s, d) activations
    per chip — NOT scaled by the stage's layer count (the stash parks only
    the stage input + output cotangent)."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=4, EP=4, DP=16, alpha=2, zero="world", schedule="zb_h1")
    depth = rm.peak_wstash("zb_h1", t.PP, t.M)
    assert depth == min(t.PP, t.M)
    b_mu_tok = t.b / t.DP / t.M
    want = depth * 2.0 * t.bytes_act * (b_mu_tok / t.EP) * t.s * m.d_model
    assert rm.wstash_bytes(m, t) == pytest.approx(want)
    # fused schedules pay nothing
    assert rm.wstash_bytes(m, _setup(PP=4, EP=4, DP=16, alpha=2,
                                     zero="world")) == 0.0


def test_planner_ranks_zb_h1_above_plain_1f1b():
    """Acceptance: for every assigned MoE arch with a feasible PP > 1
    partition, the best zb_h1 strategy outranks the best plain 1f1b one —
    identical compute and collectives, strictly smaller bubble, and the
    W-stash memory still fits Eq 11."""
    from repro.configs import ASSIGNED

    checked = []
    for name in ASSIGNED:
        arch = get_arch(name)
        if arch.moe is None or arch.num_layers < 4:
            continue
        ranked = planner.rank_strategies(
            planner.valid_strategies(
                arch, TPU_V5E, 256, batch=256, seq=4096, zero="world"
            )
        )
        zb = [s for s in ranked if s.schedule == "zb_h1"]
        fl = [s for s in ranked if s.schedule == "1f1b" and s.PP > 1]
        if not (zb and fl):
            continue
        assert ranked.index(zb[0]) < ranked.index(fl[0]), name
        assert zb[0].estimate.mem_ok
        # against plain 1f1b of the SAME partition the win is exactly the
        # bubble: smaller fraction at equal compute and wire
        same = [
            s for s in fl
            if (s.PP, s.EP, s.DP, s.alpha)
            == (zb[0].PP, zb[0].EP, zb[0].DP, zb[0].alpha)
        ]
        for s in same:
            assert zb[0].estimate.bubble_fraction < s.estimate.bubble_fraction
        checked.append(name)
    assert checked, "no arch had both zb_h1 and 1f1b PP strategies"


def test_planner_ranks_halo_above_flat_when_ep_spans_nodes():
    """Acceptance pin: whenever an EP group spans more than one node level
    of the Platform (EP > chips_per_node), the hierarchical a2a's cheaper
    exposed communication must rank it above the flat strategy of the SAME
    partition (all other knobs equal)."""
    arch = get_arch("piper-m10b-e128")
    ranked = planner.rank_strategies(
        planner.valid_strategies(
            arch, FRONTIER, 256, batch=256, seq=4096, zero="world"
        )
    )
    from repro.core.schedules import OVERLAP_BASE

    # Comm-lane schedules can hide the a2a entirely behind the schedule's
    # bracket replay, collapsing BOTH algos' exposure to zero — the halo
    # vs flat pin is about the chunk model's pricing, so compare on the
    # legacy schedules where that model is the sole account.
    spanning = [
        s for s in ranked
        if s.EP > FRONTIER.chips_per_node and s.schedule not in OVERLAP_BASE
    ]
    halo = [s for s in spanning if s.a2a_algo == "halo"]
    flat = [s for s in spanning if s.a2a_algo == "flat"]
    assert halo and flat

    def partition(s):
        return (s.PP, s.EP, s.DP, s.alpha, s.schedule, s.vstages,
                s.dispatch, s.a2a_chunks)

    pairs = 0
    flat_by_part = {}
    for f in flat:
        flat_by_part.setdefault(partition(f), f)
    for h in halo:
        f = flat_by_part.get(partition(h))
        if f is None:
            continue
        pairs += 1
        assert ranked.index(h) < ranked.index(f), (h.describe(), f.describe())
        assert h.estimate.t_a2a_exposed < f.estimate.t_a2a_exposed
    assert pairs > 0


# ---------------------------------------------------------------------------
# Serving mode
# ---------------------------------------------------------------------------


def _serve(**kw):
    base = dict(batch=16, context=2048, prefill_len=1024, EP=4, TP=1, DP=1)
    base.update(kw)
    return rm.ServeSetup(**base)


def test_kv_bytes_gqa_and_page_rounding():
    """KV bytes use the GQA head count and round context up to a page."""
    arch = get_arch("granite-moe-3b-a800m")  # 24 q heads, 8 kv heads
    m = rm.ModelShape.from_arch(arch)
    s = _serve(context=17, block_size=16)
    per_tok = rm.kv_bytes_per_token(m, s)
    assert per_tok == 2 * m.n_attn * arch.num_kv_heads * arch.head_dim * 2
    assert per_tok < 2 * m.n_attn * arch.num_heads * arch.head_dim * 2
    # 17 tokens -> 2 pages of 16
    assert rm.kv_bytes_per_seq(m, s) == 32 * per_tok


def test_decode_capacity_padding_tax_dominates_small_batch():
    """At decode batch sizes the capacity path issues >= one slot per
    expert: its padding factor explodes as batch -> 1 while ragged's stays
    bounded by the adaptive row tile."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    cap1 = rm.serving_dispatch_costs(m, _serve(batch=1, dispatch="capacity"))
    cap256 = rm.serving_dispatch_costs(
        m, _serve(batch=256, dispatch="capacity")
    )
    assert cap1.flops_factor > cap256.flops_factor >= 1.0
    rag = rm.serving_dispatch_costs(m, _serve(batch=1, dispatch="ragged"))
    assert rag.drop_rate == 0.0
    # capacity under skew drops at decode exactly as in training
    skew = rm.serving_dispatch_costs(
        m, _serve(batch=64, dispatch="capacity", imbalance=2.0)
    )
    assert skew.drop_rate > 0.0


def test_serve_estimate_monotonicity():
    """Structural sanity: latency grows with batch and context; per-chip
    goodput at fixed world size grows with batch until memory binds."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    e1 = rm.serve_estimate(m, _serve(batch=1), TPU_V5E)
    e16 = rm.serve_estimate(m, _serve(batch=16), TPU_V5E)
    e256 = rm.serve_estimate(m, _serve(batch=256), TPU_V5E)
    assert e1.t_decode < e16.t_decode < e256.t_decode
    assert e1.tokens_per_s_per_chip < e16.tokens_per_s_per_chip
    ctx_long = rm.serve_estimate(m, _serve(context=32768), TPU_V5E)
    assert ctx_long.t_decode > e16.t_decode
    assert ctx_long.mem_per_chip > e16.mem_per_chip
    # ragged streams fewer expert weights than capacity at tiny batch
    ec = rm.serve_estimate(m, _serve(batch=1, dispatch="capacity"), TPU_V5E)
    er = rm.serve_estimate(m, _serve(batch=1, dispatch="ragged"), TPU_V5E)
    assert er.t_weights < ec.t_weights


def test_serving_planner_slo_is_a_feasibility_constraint():
    """Tightening the decode SLO must only REMOVE strategies, and every
    survivor must estimate under it; with no SLO the goodput winner is at
    least as fast as any SLO-constrained winner."""
    arch = get_arch("granite-moe-3b-a800m")
    kw = dict(context=2048, prefill_len=1024)
    free = planner.valid_serving_strategies(arch, TPU_V5E, 16, **kw)
    tight = planner.valid_serving_strategies(
        arch, TPU_V5E, 16, slo_ms=5.0, **kw
    )
    assert free and tight
    assert len(tight) < len(free)
    assert all(s.estimate.t_decode * 1e3 <= 5.0 for s in tight)
    ids = {(s.EP, s.TP, s.DP, s.batch, s.dispatch) for s in free}
    assert all(
        (s.EP, s.TP, s.DP, s.batch, s.dispatch) in ids for s in tight
    )
    best_free = planner.rank_serving_strategies(free)[0]
    best_tight = planner.rank_serving_strategies(tight)[0]
    assert (
        best_free.estimate.tokens_per_s_per_chip
        >= best_tight.estimate.tokens_per_s_per_chip
    )
    # constraints: replicas tile the fleet, EP | E, fast-domain bound
    for s in free:
        assert s.world == 16
        assert (arch.moe.num_experts % s.EP) == 0
        assert s.EP <= TPU_V5E.fast_domain


def test_serving_planner_tight_slo_prefers_sharding():
    """Under a tight latency SLO the winner shards the replica (EP*TP >
    1) instead of maximizing replica count — streamed weight bytes per
    chip bind the floor."""
    arch = get_arch("granite-moe-3b-a800m")
    best = planner.best_serving_strategy(
        arch, TPU_V5E, 16, context=2048, prefill_len=1024, slo_ms=2.0
    )
    assert best is not None
    assert best.EP * best.TP > 1
    assert best.batch <= 4


def test_counts_exchange_priced():
    """The ragged EP train path prices its counts-exchange side channel;
    capacity (static slots) and EP=1 (no wire) price zero."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t_rag = rm.TrainSetup(b=256, s=4096, EP=4, dispatch="ragged")
    t_cap = rm.TrainSetup(b=256, s=4096, EP=4, dispatch="capacity")
    t_r1 = rm.TrainSetup(b=256, s=4096, EP=1, dispatch="ragged")
    assert rm.dispatch_costs(m, t_rag).counts_bytes_per_layer == (
        4.0 * 4 * (m.E / 4) * 4.0
    )
    assert rm.dispatch_costs(m, t_cap).counts_bytes_per_layer == 0.0
    assert rm.dispatch_costs(m, t_r1).counts_bytes_per_layer == 0.0


# ---------------------------------------------------------------------------
# MTBF-aware checkpoint pricing (Young-Daly)
# ---------------------------------------------------------------------------


def test_young_daly_closed_form():
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=4, EP=4, DP=16, zero="world")
    t_ckpt = rm.checkpoint_write_time(m, t, FRONTIER)
    mtbf = rm.job_mtbf(FRONTIER, t.P)
    tau = rm.young_daly_interval(t_ckpt, mtbf)
    assert tau == pytest.approx(np.sqrt(2.0 * t_ckpt * mtbf))
    # write time = fixed latency + bytes over aggregate bandwidth
    assert t_ckpt == pytest.approx(
        FRONTIER.ckpt_latency_s
        + rm.checkpoint_bytes(m) / (FRONTIER.ckpt_write_bw * t.P)
    )
    assert rm.checkpoint_bytes(m) == pytest.approx(
        m.total_params() * rm.CKPT_BYTES_PER_PARAM
    )


def test_young_daly_monotone_in_scale():
    """More chips -> shorter job MTBF -> checkpoint more often, and the
    availability-adjusted goodput factor shrinks."""
    m = rm.ModelShape.from_arch(get_arch("piper-super-545b"))
    taus, goodputs = [], []
    for dp in (8, 32, 128):
        t = _setup(PP=8, EP=32, DP=dp, zero="world")
        t_ckpt = rm.checkpoint_write_time(m, t, FRONTIER)
        mtbf = rm.job_mtbf(FRONTIER, t.P)
        tau = rm.young_daly_interval(t_ckpt, mtbf)
        taus.append(tau)
        goodputs.append(
            rm.goodput_factor(t_ckpt, mtbf, tau,
                              FRONTIER.restart_s + t_ckpt)
        )
    assert taus[0] > taus[1] > taus[2]
    assert goodputs[0] > goodputs[1] > goodputs[2]
    assert all(0.0 < g <= 1.0 for g in goodputs)


def test_estimate_surfaces_checkpoint_plan():
    """estimate() prices the checkpoint cadence end to end: interval,
    steps, goodput, and the availability-adjusted MFU."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=4, EP=4, DP=16, zero="world")
    e = rm.estimate(m, t, FRONTIER)
    assert e.t_ckpt > 0 and e.ckpt_interval_s > 0
    assert e.ckpt_every_steps >= 1
    assert e.ckpt_every_steps == max(1, int(round(e.ckpt_interval_s / e.t_step)))
    assert 0.0 < e.goodput_factor <= 1.0
    assert e.mfu_effective == pytest.approx(e.mfu * e.goodput_factor)
    assert e.mfu_effective < e.mfu  # finite MTBF always costs something


# ---------------------------------------------------------------------------
# Expert-migration pricing (Table IV link) and replica broadcast tax
# ---------------------------------------------------------------------------


def test_estimate_default_path_unchanged_by_migration_fields():
    """Omitting imbalance_post keeps estimate() bit-identical to before the
    migration link existed: the new fields are pure additions."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=2, EP=4, DP=8)
    e0 = rm.estimate(m, t, FRONTIER)
    e1 = rm.estimate(m, t, FRONTIER, imbalance_post=None)
    assert e0.t_step == e1.t_step
    assert e0.imbalance_post == 0.0
    assert e0.migrate_gain_per_step == 0.0
    assert e0.t_replicate == 0.0  # no replicas configured
    assert e0.t_migrate > 0  # the price is always quoted for MoE shapes


def test_migration_time_scales_with_layers_and_bandwidth():
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=1, EP=8)
    size, sec = rm.migration_time(m, t, FRONTIER)
    assert size > 0 and sec > 0
    # PP partitions the layer sweep: stages permute concurrently.
    _, sec_pp = rm.migration_time(m, _setup(PP=2, EP=8), FRONTIER)
    assert sec_pp == pytest.approx(sec / 2)
    # Dense shapes have nothing to migrate.
    dense = rm.ModelShape.from_arch(get_arch("smollm-360m"))
    assert rm.migration_time(dense, t, FRONTIER) == (0.0, 0.0)


def test_estimate_prices_rebalance_gain():
    """imbalance_post quotes the modeled recovery: a skewed setup that
    rebalances toward 1.0 gains step time, and the gain amortized over a
    migration window can clear the transfer cost."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(PP=2, EP=8, DP=8, imbalance=1.6)
    e = rm.estimate(m, t, FRONTIER, imbalance_post=1.05)
    assert e.imbalance_post == 1.05
    assert e.migrate_gain_per_step > 0
    assert e.t_migrate > 0
    # The skewed step is exactly the balanced step plus the quoted gain.
    balanced = rm.estimate(
        m, _setup(PP=2, EP=8, DP=8, imbalance=1.05), FRONTIER
    )
    assert e.t_step - balanced.t_step == pytest.approx(e.migrate_gain_per_step)


def test_replica_broadcast_tax():
    """Replica channels pay a per-step psum-broadcast of the replicated
    experts' weights; zero replicas costs nothing (bit-identical)."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t0 = _setup(PP=2, EP=8, DP=8)
    t2 = _setup(PP=2, EP=8, DP=8, replicas=2)
    e0 = rm.estimate(m, t0, FRONTIER)
    e2 = rm.estimate(m, t2, FRONTIER)
    assert e0.t_replicate == 0.0
    assert e2.t_replicate > 0.0
    assert e2.t_step >= e0.t_step
    # More channels, more tax.
    e4 = rm.estimate(m, _setup(PP=2, EP=8, DP=8, replicas=4), FRONTIER)
    assert e4.t_replicate == pytest.approx(2 * e2.t_replicate)


def test_planner_describe_surfaces_migration():
    """Strategy.describe() renders the migration quote only when a
    post-rebalance imbalance was priced."""
    arch = get_arch("granite-moe-3b-a800m")
    plain = planner.valid_strategies(
        arch, FRONTIER, 64, batch=256, seq=4096
    )
    priced = planner.valid_strategies(
        arch, FRONTIER, 64, batch=256, seq=4096, imbalance_post=1.05,
    )
    assert plain and priced
    assert all("migrate=" not in st.describe() for st in plain)
    assert any("migrate=" in st.describe() for st in priced)
