"""Resource model (paper Eq 1-6, 12) and planner (Eq 7-11) tests."""

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import planner, resource_model as rm, schedule_sim as ss
from repro.core.platform import FRONTIER, TPU_V5E


def _setup(**kw):
    base = dict(b=256, s=4096)
    base.update(kw)
    return rm.TrainSetup(**base)


def test_memory_eq1_vs_eq2_consistency():
    """EP=1, DP=1 EDP memory equals the unpartitioned bound (same policy)."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(EP=1, DP=1, zero="none", framework_overhead=0.0)
    mu = rm.memory_unpartitioned(m, t)
    medp = rm.memory_edp(m, t)
    # memory_edp includes embeddings which Eq 1 (layer-only) omits
    embed = t.bytes_per_param * 2 * m.vocab * m.d_model
    assert medp == pytest.approx(mu + embed, rel=0.01)


def test_memory_monotone_in_ep():
    m = rm.ModelShape.from_arch(get_arch("piper-super-545b"))
    t8 = _setup(EP=8, zero="none")
    t32 = _setup(EP=32, zero="none")
    assert rm.memory_edp(m, t32) < rm.memory_edp(m, t8)


def test_1f1b_stage_skew_eq5():
    """Eq 5: stage-0 holds (PP-1)x more in-flight activation than the last;
    the skew equals the closed form."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t = _setup(PP=4, EP=16, alpha=2, zero="none")
    skew = rm.memory_1f1b_skew(m, t)
    m0 = rm.memory_pp_1f1b(m, t, 0)
    mlast = rm.memory_pp_1f1b(m, t, t.PP - 1)
    assert skew == pytest.approx(m0 - mlast)
    assert skew > 0


def test_1f1b_peak_matches_schedule_sim():
    """Paper Eq 4 peak in-flight microbatches == discrete-event simulation."""
    for PP, M in [(2, 4), (4, 8), (8, 16)]:
        sim = ss.one_f_one_b(PP, M)
        assert sim.peak_in_flight == ss.peak_activations_1f1b(PP)


def test_gpipe_holds_all_microbatches():
    sim = ss.gpipe(4, 8)
    assert sim.peak_in_flight == [8, 8, 8, 8]


def test_bubble_fraction():
    from repro.core.pipeline import bubble_fraction

    for PP, M in [(2, 4), (4, 8)]:
        sim = ss.one_f_one_b(PP, M, t_fwd=1.0, t_bwd=2.0)
        assert sim.bubble_fraction == pytest.approx(
            bubble_fraction(PP, M), abs=0.02
        )


def test_a2a_bound_eq6_scaling():
    """Eq 6: a2a time scales ~1/EP at fixed token count and grows with s."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t8 = _setup(EP=8)
    t16 = _setup(EP=16)
    b8 = rm.t_a2a_lower_bound(m, t8, FRONTIER)
    b16 = rm.t_a2a_lower_bound(m, t16, FRONTIER)
    assert b16 < b8
    t_long = _setup(EP=8, s=8192)
    assert rm.t_a2a_lower_bound(m, t_long, FRONTIER) > b8


def test_planner_constraints():
    """Every emitted strategy satisfies Eq 7-11."""
    arch = get_arch("piper-super-545b")
    strategies = planner.valid_strategies(
        arch, FRONTIER, 512, batch=256, seq=4096
    )
    assert strategies
    E = arch.moe.num_experts
    for s in strategies:
        assert s.PP * s.EP * s.DP == 512  # Eq 7
        assert E % s.EP == 0  # Eq 8
        assert s.PP <= arch.num_layers  # Eq 9
        assert s.EP <= FRONTIER.fast_domain  # Eq 10
        assert s.estimate.mem_ok  # Eq 11


def test_planner_mfu_in_paper_band():
    """Paper: SOTA MoE at 20-50% MFU on Frontier; X-MoE super at 5%."""
    best = planner.best_strategy(
        get_arch("piper-super-545b"), FRONTIER, 512, batch=256, seq=4096
    )
    assert best is not None
    assert 0.15 < best.estimate.mfu < 0.55


def test_planner_min_chips_fig10():
    """Fig 10: the 545B/615B-class model needs >= 64 nodes worth of HBM
    without ZeRO (paper trains it from 64 nodes = 512 GCDs)."""
    arch = get_arch("piper-super-545b")
    mc = planner.min_chips(
        arch, FRONTIER, batch=256, seq=4096,
        chip_counts=[8, 16, 32, 64, 128, 256, 512],
    )
    assert mc is not None and mc >= 64


def test_all_assigned_archs_plannable_on_v5e():
    from repro.configs import ASSIGNED

    for name in ASSIGNED:
        s = planner.best_strategy(
            get_arch(name), TPU_V5E, 256, batch=256, seq=4096, zero="world"
        )
        assert s is not None, name
