"""Resource model (paper Eq 1-6, 12) and planner (Eq 7-11) tests."""

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import planner, resource_model as rm, schedule_sim as ss
from repro.core.platform import FRONTIER, TPU_V5E


def _setup(**kw):
    base = dict(b=256, s=4096)
    base.update(kw)
    return rm.TrainSetup(**base)


def test_memory_eq1_vs_eq2_consistency():
    """EP=1, DP=1 EDP memory equals the unpartitioned bound (same policy)."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    t = _setup(EP=1, DP=1, zero="none", framework_overhead=0.0)
    mu = rm.memory_unpartitioned(m, t)
    medp = rm.memory_edp(m, t)
    # memory_edp includes embeddings which Eq 1 (layer-only) omits
    embed = t.bytes_per_param * 2 * m.vocab * m.d_model
    assert medp == pytest.approx(mu + embed, rel=0.01)


def test_memory_monotone_in_ep():
    m = rm.ModelShape.from_arch(get_arch("piper-super-545b"))
    t8 = _setup(EP=8, zero="none")
    t32 = _setup(EP=32, zero="none")
    assert rm.memory_edp(m, t32) < rm.memory_edp(m, t8)


def test_1f1b_stage_skew_eq5():
    """Eq 5: stage-0 holds (PP-1)x more in-flight activation than the last;
    the skew equals the closed form."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t = _setup(PP=4, EP=16, alpha=2, zero="none")
    skew = rm.memory_1f1b_skew(m, t)
    m0 = rm.memory_pp_1f1b(m, t, 0)
    mlast = rm.memory_pp_1f1b(m, t, t.PP - 1)
    assert skew == pytest.approx(m0 - mlast)
    assert skew > 0


def test_1f1b_peak_matches_schedule_sim():
    """Paper Eq 4 peak in-flight microbatches == discrete-event simulation."""
    for PP, M in [(2, 4), (4, 8), (8, 16)]:
        sim = ss.one_f_one_b(PP, M)
        assert sim.peak_in_flight == ss.peak_activations_1f1b(PP)


def test_gpipe_holds_all_microbatches():
    sim = ss.gpipe(4, 8)
    assert sim.peak_in_flight == [8, 8, 8, 8]


def test_bubble_fraction():
    from repro.core.pipeline import bubble_fraction

    for PP, M in [(2, 4), (4, 8)]:
        sim = ss.one_f_one_b(PP, M, t_fwd=1.0, t_bwd=2.0)
        assert sim.bubble_fraction == pytest.approx(
            bubble_fraction(PP, M), abs=0.02
        )


def test_a2a_bound_eq6_scaling():
    """Eq 6: a2a time scales ~1/EP at fixed token count and grows with s."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t8 = _setup(EP=8)
    t16 = _setup(EP=16)
    b8 = rm.t_a2a_lower_bound(m, t8, FRONTIER)
    b16 = rm.t_a2a_lower_bound(m, t16, FRONTIER)
    assert b16 < b8
    t_long = _setup(EP=8, s=8192)
    assert rm.t_a2a_lower_bound(m, t_long, FRONTIER) > b8


def test_planner_constraints():
    """Every emitted strategy satisfies Eq 7-11."""
    arch = get_arch("piper-super-545b")
    strategies = planner.valid_strategies(
        arch, FRONTIER, 512, batch=256, seq=4096
    )
    assert strategies
    E = arch.moe.num_experts
    for s in strategies:
        assert s.PP * s.EP * s.DP == 512  # Eq 7
        assert E % s.EP == 0  # Eq 8
        assert s.PP <= arch.num_layers  # Eq 9
        assert s.EP <= FRONTIER.fast_domain  # Eq 10
        assert s.estimate.mem_ok  # Eq 11


def test_planner_mfu_in_paper_band():
    """Paper: SOTA MoE at 20-50% MFU on Frontier; X-MoE super at 5%."""
    best = planner.best_strategy(
        get_arch("piper-super-545b"), FRONTIER, 512, batch=256, seq=4096
    )
    assert best is not None
    assert 0.15 < best.estimate.mfu < 0.55


def test_planner_min_chips_fig10():
    """Fig 10: the 545B/615B-class model needs >= 64 nodes worth of HBM
    without ZeRO (paper trains it from 64 nodes = 512 GCDs)."""
    arch = get_arch("piper-super-545b")
    mc = planner.min_chips(
        arch, FRONTIER, batch=256, seq=4096,
        chip_counts=[8, 16, 32, 64, 128, 256, 512],
    )
    assert mc is not None and mc >= 64


def test_all_assigned_archs_plannable_on_v5e():
    from repro.configs import ASSIGNED

    for name in ASSIGNED:
        s = planner.best_strategy(
            get_arch(name), TPU_V5E, 256, batch=256, seq=4096, zero="world"
        )
        assert s is not None, name


def test_interleaved_memory_between_1f1b_and_double():
    """The interleaved Eq-4 analogue: more residual memory than plain 1F1B
    (deeper warmup), but the chunks are 1/V of a stage, so the activation
    term stays within ~2x of Eq 4."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e16"))
    t1 = _setup(PP=4, EP=16, alpha=2, zero="none", schedule="1f1b")
    t2 = _setup(PP=4, EP=16, alpha=2, zero="none",
                schedule="interleaved_1f1b", vstages=2)
    m1 = rm.memory_pp(m, t1, 0)
    m2 = rm.memory_pp(m, t2, 0)
    act1 = m1 - rm.static_state_bytes(m, t1, m.L / t1.PP) - t1.framework_overhead
    act2 = m2 - rm.static_state_bytes(m, t2, m.L / t2.PP) - t2.framework_overhead
    assert m1 < m2
    assert act2 < 2.0 * act1 + 1e-6
    # vstages=1 interleaving is plain 1F1B, in memory too
    t0 = _setup(PP=4, EP=16, alpha=2, zero="none",
                schedule="interleaved_1f1b", vstages=1)
    assert rm.memory_pp(m, t0, 0) == m1


def test_planner_ranks_interleaved_above_plain_1f1b():
    """Acceptance: for at least one assigned MoE arch, interleaved 1F1B is
    feasible (its V× residual memory still fits Eq 4/11) and outranks every
    plain 1f1b strategy — the lower Eq-3 bubble wins at equal partition."""
    from repro.configs import ASSIGNED

    won = []
    for name in ASSIGNED:
        arch = get_arch(name)
        if arch.moe is None or arch.num_layers < 4:
            continue
        ranked = planner.rank_strategies(
            planner.valid_strategies(
                arch, TPU_V5E, 256, batch=256, seq=4096, zero="world"
            )
        )
        il = [s for s in ranked if s.schedule == "interleaved_1f1b"]
        fl = [s for s in ranked if s.schedule == "1f1b" and s.PP > 1]
        if il and fl and ranked.index(il[0]) < ranked.index(fl[0]):
            best = il[0]
            assert best.vstages > 1
            assert best.estimate.mem_ok
            # against plain 1f1b of the SAME partition the win is exactly
            # the 1/V bubble (same compute, same collectives)
            same = [
                s for s in fl
                if (s.PP, s.EP, s.DP, s.alpha)
                == (best.PP, best.EP, best.DP, best.alpha)
            ]
            for s in same:
                assert best.estimate.bubble_fraction < s.estimate.bubble_fraction
            won.append(name)
    assert won, "no arch ranks interleaved above plain 1f1b"


def test_planner_vstages_are_executor_valid():
    """Regression: V candidates must divide the BLOCK-PATTERN reps per
    stage (the executor's chunk unit), not raw layers — on hybrid archs
    (pattern period > 1) the two differ and an invalid V crashes
    ``pipeline._stage_block_params``."""
    from repro.core.planner import _schedule_candidates

    for name in ("gemma2-9b", "jamba-1.5-large-398b", "granite-moe-3b-a800m"):
        arch = get_arch(name)
        reps = arch.num_layers // len(arch.block_pattern)
        for PP in (2, 3, 4, 8):
            for schedule, V in _schedule_candidates(arch, PP):
                if schedule != "interleaved_1f1b":
                    assert V == 1
                    continue
                assert V > 1 and reps % (PP * V) == 0, (name, PP, V, reps)


def test_interleaved_estimate_tradeoffs():
    """Same partition, V=2: smaller bubble, more p2p, more stage-0 memory."""
    m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
    kw = dict(PP=4, EP=4, DP=16, alpha=2, zero="world")
    e1 = rm.estimate(m, _setup(schedule="1f1b", **kw), TPU_V5E)
    e2 = rm.estimate(
        m, _setup(schedule="interleaved_1f1b", vstages=2, **kw), TPU_V5E
    )
    assert e2.bubble_fraction == pytest.approx(e1.bubble_fraction / 2)
    assert e2.t_p2p == pytest.approx(2 * e1.t_p2p)
    assert e2.mem_stage0 > e1.mem_stage0
