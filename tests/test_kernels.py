"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.moe_gemm import ops as mm_ops
from repro.kernels.moe_gemm import ref as mm_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "E,M,K,N",
    [(2, 16, 32, 16), (4, 128, 64, 512), (3, 100, 96, 56), (8, 256, 128, 128),
     (1, 64, 512, 64)],
)
def test_grouped_matmul(E, M, K, N, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (E, M, K), dtype)
    w = jax.random.normal(k2, (E, K, N), dtype)
    out = mm_ops.grouped_matmul(x, w, interpret=True)
    ref = mm_ref.grouped_matmul(x, w).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 8,
    )


@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
def test_grouped_ffn(activation):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    E, C, d, f = 4, 64, 48, 96
    toks = jax.random.normal(ks[0], (E, C, d))
    wu = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    out = mm_ops.grouped_ffn(toks, wu, wg, wd, activation, interpret=True)
    ref = mm_ref.grouped_ffn(toks, wu, wg, wd, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,window,cap",
    [
        (2, 4, 2, 128, 32, None, None),
        (1, 8, 8, 256, 64, 64, None),
        (2, 4, 1, 96, 16, None, 50.0),
        (1, 2, 2, 64, 128, 32, 30.0),
    ],
)
def test_flash_attention(b, hq, hkv, s, d, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = fa_ops.flash_attention(
        q, k, v, window=window, logit_softcap=cap, interpret=True,
        bq=64, bk=64,
    )
    ref = fa_ref.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, softcap=cap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 4,
    )


@pytest.mark.parametrize(
    "b,nc,cl,h,p,n", [(1, 2, 32, 4, 16, 8), (2, 2, 64, 8, 32, 16)]
)
def test_ssd_intra_chunk(b, nc, cl, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, nc, cl, h, p))
    dA = -jnp.abs(jax.random.normal(ks[1], (b, nc, cl, h))) * 0.1
    B = jax.random.normal(ks[2], (b, nc, cl, h, n))
    C = jax.random.normal(ks[3], (b, nc, cl, h, n))
    y = ssd_ops.ssd_intra_chunk(x, dA, B, C, interpret=True)
    fold = lambda t: t.reshape((b * nc,) + t.shape[2:])
    ref = ssd_ref.ssd_intra_chunk(fold(x), fold(dA), fold(B), fold(C))
    np.testing.assert_allclose(
        np.asarray(y).reshape(ref.shape), np.asarray(ref), atol=3e-5
    )


def test_full_model_pallas_matches_xla():
    from repro.configs import get_arch
    from repro.models.model import LanguageModel, init_params
    from repro.sharding import single_device_plan

    for name in ["granite-moe-3b-a800m", "mamba2-370m", "gemma2-9b"]:
        arch = get_arch(name).reduced()
        plan = single_device_plan(arch)
        with plan.mesh:
            params = init_params(arch, jax.random.PRNGKey(0))
            toks = jax.random.randint(
                jax.random.PRNGKey(5), (2, 64), 0, arch.vocab_size
            )
            lx, _, _ = jax.jit(
                LanguageModel(arch, plan, impl="xla").forward
            )(params, {"tokens": toks})
            lp, _, _ = jax.jit(
                LanguageModel(arch, plan, impl="pallas").forward
            )(params, {"tokens": toks})
            np.testing.assert_allclose(
                np.asarray(lx), np.asarray(lp), atol=5e-5
            )
