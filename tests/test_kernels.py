"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.moe_gemm import ops as mm_ops
from repro.kernels.moe_gemm import ref as mm_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "E,M,K,N",
    [(2, 16, 32, 16), (4, 128, 64, 512), (3, 100, 96, 56), (8, 256, 128, 128),
     (1, 64, 512, 64)],
)
def test_grouped_matmul(E, M, K, N, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (E, M, K), dtype)
    w = jax.random.normal(k2, (E, K, N), dtype)
    out = mm_ops.grouped_matmul(x, w, interpret=True)
    ref = mm_ref.grouped_matmul(x, w).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 8,
    )


@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
def test_grouped_ffn(activation):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    E, C, d, f = 4, 64, 48, 96
    toks = jax.random.normal(ks[0], (E, C, d))
    wu = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    out = mm_ops.grouped_ffn(toks, wu, wg, wd, activation, interpret=True)
    ref = mm_ref.grouped_ffn(toks, wu, wg, wd, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_grouped_ffn_keeps_fp32_intermediate():
    """Precision regression: the hidden activation must stay fp32 between
    the up/gate and down launches.  The old bf16 round-trip's mean error vs
    an fp64 reference is ~2.2e-3 at f=1024; keeping fp32 gives ~1.4e-3 —
    the 1.8e-3 gate fails the truncating version on both widths."""
    for f in (512, 1024):
        E, C, d = 2, 32, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        toks = jax.random.normal(ks[0], (E, C, d)).astype(jnp.bfloat16)
        wu = (jax.random.normal(ks[1], (E, d, f)) * 0.1).astype(jnp.bfloat16)
        wg = (jax.random.normal(ks[2], (E, d, f)) * 0.1).astype(jnp.bfloat16)
        wd = (jax.random.normal(ks[3], (E, f, d)) * 0.1).astype(jnp.bfloat16)
        t64, u64, g64, d64 = (
            np.asarray(a, np.float64) for a in (toks, wu, wg, wd)
        )
        gate = np.einsum("ecd,edf->ecf", t64, g64)
        up = np.einsum("ecd,edf->ecf", t64, u64)
        h64 = gate / (1 + np.exp(-gate)) * up
        ref = np.einsum("ecf,efd->ecd", h64, d64)
        out = np.asarray(
            mm_ops.grouped_ffn(toks, wu, wg, wd, "swiglu", interpret=True),
            np.float64,
        )
        mean_rel = np.abs(out - ref).mean() / np.abs(ref).mean()
        assert mean_rel < 1.8e-3, (f, mean_rel)


RAGGED_COUNTS = [
    [7, 0, 83, 1, 9],  # skewed + empty expert
    [0, 0, 0, 100],  # all tokens to one expert
    [25, 25, 25, 25],  # uniform
    [100],  # E = 1
    [1, 1, 1, 1, 1, 96, 1, 1],  # near-degenerate skew
]


def _ragged_case(counts, K, N, dtype, seed=0):
    counts = np.asarray(counts)
    E, T = len(counts), int(counts.sum())
    offs = jnp.asarray(np.concatenate([[0], np.cumsum(counts)]), jnp.int32)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (T, K), dtype)
    w = jax.random.normal(k2, (E, K, N), dtype) * 0.2
    return x, w, offs, E, T


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("counts", RAGGED_COUNTS)
def test_ragged_matmul(counts, dtype):
    x, w, offs, E, T = _ragged_case(counts, K=48, N=64, dtype=dtype)
    out = mm_ops.ragged_matmul(x, w, offs, interpret=True, bm=16)
    ref = mm_ref.ragged_matmul(x, w, offs)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 8,
    )


@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
@pytest.mark.parametrize("counts", RAGGED_COUNTS)
def test_ragged_ffn_matches_oracle(counts, activation):
    x, _, offs, E, T = _ragged_case(counts, K=32, N=32, dtype=jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    d, f = 32, 48
    wu = jax.random.normal(ks[0], (E, d, f)) * 0.2
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.2 if activation == "swiglu" else None
    wd = jax.random.normal(ks[2], (E, f, d)) * 0.2
    out = mm_ops.ragged_ffn(x, wu, wg, wd, offs, activation,
                            interpret=True, bm=16)
    ref = mm_ref.ragged_ffn(x, wu, wg, wd, offs, activation)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
def test_ragged_ffn_custom_vjp_matches_jax_grad(activation):
    """The hand-written backward (two ragged GEMMs + ragged dgrads) must
    equal jax.grad through the differentiable XLA reference."""
    counts = [7, 0, 83, 1, 9]
    x, _, offs, E, T = _ragged_case(counts, K=32, N=32, dtype=jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    d, f = 32, 48
    wu = jax.random.normal(ks[0], (E, d, f)) * 0.2
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.2
    wd = jax.random.normal(ks[2], (E, f, d)) * 0.2
    cot = jnp.cos(jnp.arange(T * d, dtype=jnp.float32)).reshape(T, d)

    def kernel_loss(x, wu, wg, wd):
        wg_ = wg if activation == "swiglu" else None
        y = mm_ops.ragged_ffn(x, wu, wg_, wd, offs, activation,
                              interpret=True, bm=16)
        return (y * cot).sum()

    def ref_loss(x, wu, wg, wd):
        wg_ = wg if activation == "swiglu" else None
        y = mm_ref.ragged_ffn(x, wu, wg_, wd, offs, activation)
        return (y * cot).sum()

    gk = jax.grad(kernel_loss, argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    gr = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    for name, a, b in zip(("dx", "dwu", "dwg", "dwd"), gk, gr):
        if activation != "swiglu" and name == "dwg":
            continue  # w_gate unused: both grads are zero/absent
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5,
            err_msg=name,
        )


def test_ragged_matmul_empty_tail_rows_zero():
    """Rows beyond offsets[-1] (padding) must come back exactly zero."""
    counts = [5, 3]
    offs = jnp.asarray([0, 5, 8], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))  # 8 pad rows
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    out = np.asarray(mm_ops.ragged_matmul(x, w, offs, interpret=True, bm=8))
    assert (out[8:] == 0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,window,cap",
    [
        (2, 4, 2, 128, 32, None, None),
        (1, 8, 8, 256, 64, 64, None),
        (2, 4, 1, 96, 16, None, 50.0),
        (1, 2, 2, 64, 128, 32, 30.0),
    ],
)
def test_flash_attention(b, hq, hkv, s, d, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = fa_ops.flash_attention(
        q, k, v, window=window, logit_softcap=cap, interpret=True,
        bq=64, bk=64,
    )
    ref = fa_ref.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, softcap=cap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 4,
    )


@pytest.mark.parametrize(
    "b,nc,cl,h,p,n", [(1, 2, 32, 4, 16, 8), (2, 2, 64, 8, 32, 16)]
)
def test_ssd_intra_chunk(b, nc, cl, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, nc, cl, h, p))
    dA = -jnp.abs(jax.random.normal(ks[1], (b, nc, cl, h))) * 0.1
    B = jax.random.normal(ks[2], (b, nc, cl, h, n))
    C = jax.random.normal(ks[3], (b, nc, cl, h, n))
    y = ssd_ops.ssd_intra_chunk(x, dA, B, C, interpret=True)
    fold = lambda t: t.reshape((b * nc,) + t.shape[2:])
    ref = ssd_ref.ssd_intra_chunk(fold(x), fold(dA), fold(B), fold(C))
    np.testing.assert_allclose(
        np.asarray(y).reshape(ref.shape), np.asarray(ref), atol=3e-5
    )


def test_full_model_pallas_matches_xla():
    from repro.configs import get_arch
    from repro.models.model import LanguageModel, init_params
    from repro.sharding import single_device_plan

    for name in ["granite-moe-3b-a800m", "mamba2-370m", "gemma2-9b"]:
        arch = get_arch(name).reduced()
        plan = single_device_plan(arch)
        with plan.mesh:
            params = init_params(arch, jax.random.PRNGKey(0))
            toks = jax.random.randint(
                jax.random.PRNGKey(5), (2, 64), 0, arch.vocab_size
            )
            lx, _, _ = jax.jit(
                LanguageModel(arch, plan, impl="xla").forward
            )(params, {"tokens": toks})
            lp, _, _ = jax.jit(
                LanguageModel(arch, plan, impl="pallas").forward
            )(params, {"tokens": toks})
            np.testing.assert_allclose(
                np.asarray(lx), np.asarray(lp), atol=5e-5
            )
