"""Chunked double-buffered EP all-to-all: closed-form pricing, resource-model
exposure, and the planner's (a2a_algo x a2a_chunks) knob.

The overlap closed form (comm_model.overlapped_layer_time) is

    T = c + (K-1) * max(c, p) + p,   c = dispatch+combine of 1/K the rows,
                                     p = t_comp / K

These tests pin its boundary behavior (K=1 == serial; latency tax makes
pure chunking never free; a finite interior optimum K exists), that the
resource model's exposed-a2a term collapses to the serial Eq-6 number
bit-for-bit at the defaults, and that the planner enumerates and ranks the
full algo x chunks grid end-to-end.
"""

import pytest

from repro.configs import get_arch
from repro.configs.base import A2A_ALGOS, A2A_CHUNK_CANDIDATES
from repro.core import comm_model as cm, planner, resource_model as rm
from repro.core.platform import FRONTIER, TPU_V5E

CASE = cm.A2ACase(n_ranks=16, row_bytes=1e6)


def _setup(**kw):
    base = dict(b=256, s=4096, PP=4, EP=16, DP=4, zero="world")
    base.update(kw)
    return rm.TrainSetup(**base)


# ---------------------------------------------------------------------------
# comm_model closed forms
# ---------------------------------------------------------------------------


def test_k1_reduces_to_serial():
    for algo in A2A_ALGOS:
        for t_comp in (0.0, 3e-3):
            t = cm.overlapped_layer_time(CASE, FRONTIER, algo, 1, t_comp)
            serial = 2.0 * cm.a2a_time(CASE, FRONTIER, algo) + t_comp
            assert t == pytest.approx(serial)
            assert cm.exposed_a2a_time(
                CASE, FRONTIER, algo, 1, t_comp
            ) == pytest.approx(serial - t_comp)


def test_pure_chunking_is_never_free():
    """With no compute to hide behind, K transfers of 1/K rows pay the
    per-collective latency K times — strictly increasing in K."""
    ts = [cm.chunked_a2a_time(CASE, FRONTIER, "flat", K)
          for K in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(ts, ts[1:]))
    assert ts[0] == pytest.approx(cm.a2a_time(CASE, FRONTIER, "flat"))


def test_overlap_shrinks_exposure_in_compute_rich_regime():
    """When per-chunk compute dominates per-chunk transfer, the exposed
    a2a falls toward the single fill chunk (~serial/K)."""
    t_comp = 20.0 * cm.a2a_time(CASE, FRONTIER, "flat")
    e1 = cm.exposed_a2a_time(CASE, FRONTIER, "flat", 1, t_comp)
    e4 = cm.exposed_a2a_time(CASE, FRONTIER, "flat", 4, t_comp)
    assert 0.0 < e4 < e1
    assert cm.overlapped_layer_time(
        CASE, FRONTIER, "flat", 4, t_comp
    ) < cm.overlapped_layer_time(CASE, FRONTIER, "flat", 1, t_comp)
    # and the layer can never beat the compute-only lower bound
    assert cm.overlapped_layer_time(
        CASE, FRONTIER, "flat", 4, t_comp
    ) > t_comp


def test_finite_interior_optimal_k():
    """The latency tax vs fill-chunk amortization tradeoff yields an
    interior argmin over K: more chunks stop helping at some point."""
    t_comp = 4.0 * cm.a2a_time(CASE, FRONTIER, "flat")
    ks = list(range(1, 257))
    times = [cm.overlapped_layer_time(CASE, FRONTIER, "flat", K, t_comp)
             for K in ks]
    k_star = ks[times.index(min(times))]
    assert 1 < k_star < 256
    assert cm.best_a2a_config(
        CASE, FRONTIER, t_comp, algos=("flat",), chunk_candidates=tuple(ks)
    )["chunks"] == k_star


def test_best_a2a_config_minimizes_grid():
    t_comp = 1e-3
    best = cm.best_a2a_config(CASE, FRONTIER, t_comp)
    grid = [cm.overlapped_layer_time(CASE, FRONTIER, a, K, t_comp)
            for a in A2A_ALGOS for K in A2A_CHUNK_CANDIDATES]
    assert best["t_layer"] == pytest.approx(min(grid))
    assert best["t_exposed"] == pytest.approx(best["t_layer"] - t_comp)


# ---------------------------------------------------------------------------
# resource_model exposure
# ---------------------------------------------------------------------------


def test_estimate_defaults_price_serial_a2a_exactly():
    """flat x K=1 must reproduce the serial Eq-6 charge bit-for-bit — the
    overlap path may not perturb existing estimates."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e128"))
    e = rm.estimate(m, _setup(), FRONTIER)
    assert e.t_a2a_exposed == e.t_a2a
    assert e.a2a_overlap_saving == 0.0
    assert e.a2a_algo == "flat" and e.a2a_chunks == 1


def test_estimate_chunked_overlap_saving():
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e128"))
    e1 = rm.estimate(m, _setup(), FRONTIER)
    e8 = rm.estimate(m, _setup(a2a_chunks=8), FRONTIER)
    assert 0.0 < e8.t_a2a_exposed < e8.t_a2a
    assert e8.a2a_overlap_saving == pytest.approx(e8.t_a2a - e8.t_a2a_exposed)
    assert e8.t_a2a == e1.t_a2a  # the serial Eq-6 reference is unchanged
    assert e8.t_step < e1.t_step
    assert e8.mfu > e1.mfu
    # halo composes with chunking: EP=16 spans Frontier nodes, so the
    # hierarchical per-chunk transfer is cheaper and exposure shrinks more
    eh = rm.estimate(m, _setup(a2a_algo="halo", a2a_chunks=8), FRONTIER)
    assert eh.t_a2a_exposed < e8.t_a2a_exposed


def test_a2a_case_matches_eq6_bytes():
    """The A2ACase handed to comm_model carries exactly the Eq-6 wire
    bytes: row_bytes * (EP-1) == a2a_bytes_per_gpu."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e128"))
    t = _setup()
    case = rm.a2a_case(m, t)
    assert case.n_ranks == t.EP
    assert case.row_bytes * (t.EP - 1) == pytest.approx(
        rm.a2a_bytes_per_gpu(m, t)
    )


def test_moe_layer_compute_time_scaling():
    """Forward expert-GEMM seconds per rank: grows with tokens (b*s),
    shrinks as the DP*EP token split widens (the per-rank token count —
    and with it the skinny-GEMM efficiency — moves too, so the scaling is
    monotone rather than exactly linear)."""
    m = rm.ModelShape.from_arch(get_arch("piper-m10b-e128"))
    t = _setup()
    p = rm.moe_layer_compute_time(m, t, FRONTIER)
    assert p > 0
    assert rm.moe_layer_compute_time(m, _setup(b=512), FRONTIER) > p
    assert rm.moe_layer_compute_time(m, _setup(DP=8), FRONTIER) < p


def test_setup_validates_a2a_fields():
    with pytest.raises(AssertionError):
        _setup(a2a_algo="nccl")
    with pytest.raises(AssertionError):
        _setup(a2a_chunks=0)


# ---------------------------------------------------------------------------
# planner knob
# ---------------------------------------------------------------------------


def test_planner_enumerates_full_a2a_grid_when_ep_spans_nodes():
    arch = get_arch("piper-m10b-e128")
    strategies = planner.valid_strategies(
        arch, FRONTIER, 256, batch=256, seq=4096, zero="world"
    )
    spanning = [s for s in strategies if s.EP > FRONTIER.chips_per_node]
    assert spanning
    combos = {(s.a2a_algo, s.a2a_chunks) for s in spanning}
    assert combos == {(a, K) for a in A2A_ALGOS
                      for K in A2A_CHUNK_CANDIDATES}


def test_planner_prunes_halo_inside_one_node():
    """halo inside a single node is the flat collective plus extra latency
    — the probe gate must drop it."""
    arch = get_arch("piper-m10b-e128")
    strategies = planner.valid_strategies(
        arch, FRONTIER, 256, batch=256, seq=4096, zero="world"
    )
    local = [s for s in strategies if 1 < s.EP <= FRONTIER.chips_per_node]
    assert local
    assert all(s.a2a_algo == "flat" for s in local)


def test_dense_arch_gets_default_a2a_only():
    strategies = planner.valid_strategies(
        get_arch("yi-9b"), TPU_V5E, 64, batch=64, seq=4096, zero="world"
    )
    assert strategies
    assert all(
        (s.a2a_algo, s.a2a_chunks) == ("flat", 1) for s in strategies
    )
