"""HALO hierarchical all-to-all == flat oracle, as a property.

The halo module's contract is that ``hierarchical_all_to_all`` is
bit-for-bit interchangeable with ``lax.all_to_all`` (flat) for EVERY
factorization ep = g1 x M — values AND gradients (the collective is linear;
its transpose must be the same collective reversed).  This module sweeps
ep in {2, 4, 8} x all proper g1 divisors on real host-device meshes in a
re-exec'd child (8 forced host devices, like test_multidevice), and
property-tests the pure chunk geometry helpers directly (with randomized
hypothesis sweeps when the dev extra is installed).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import halo

# ---------------------------------------------------------------------------
# Pure chunk geometry (no devices needed)
# ---------------------------------------------------------------------------


def _check_slices(total, K):
    slices = halo.chunk_slices(total, K)
    assert len(slices) <= max(K, 1)
    if total == 0:
        assert slices == [(0, 0)]
        return slices
    # exact disjoint cover in order
    pos = 0
    for start, size in slices:
        assert start == pos and size > 0
        pos += size
    assert pos == total
    # only the tail chunk may be short
    sizes = [s for _, s in slices]
    assert all(s == sizes[0] for s in sizes[:-1])
    assert sizes[-1] <= sizes[0]
    return slices


def test_chunk_slices_deterministic_sweep():
    for total in (0, 1, 2, 3, 7, 8, 16, 17, 64, 100):
        for K in (1, 2, 3, 4, 8, 200):
            _check_slices(total, K)


def test_chunk_slices_k1_is_monolithic():
    assert halo.chunk_slices(37, 1) == [(0, 37)]


def test_chunk_slices_tail():
    # K=3 over 16 rows: ceil -> 6,6,4 (only the tail is short)
    assert halo.chunk_slices(16, 3) == [(0, 6), (6, 6), (12, 4)]


def test_chunk_slices_degenerates_to_single_rows():
    assert halo.chunk_slices(3, 8) == [(0, 1), (1, 1), (2, 1)]


def test_pick_inner_divides():
    for ep in (2, 4, 8, 16, 64):
        g1 = halo._pick_inner(ep)
        assert ep % g1 == 0 and 1 <= g1 <= 4


def test_group_partitions():
    for ep, g1 in ((4, 2), (8, 2), (8, 4)):
        lanes = halo.lane_groups(ep, g1)
        nodes = halo.node_groups(ep, g1)
        for groups, size in ((lanes, g1), (nodes, ep // g1)):
            flat = sorted(r for g in groups for r in g)
            assert flat == list(range(ep))
            assert all(len(g) == size for g in groups)


try:
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=200)
    @given(st.integers(0, 4096), st.integers(1, 64))
    def test_chunk_slices_property(total, K):
        _check_slices(total, K)
except ImportError:  # hypothesis is a dev extra; deterministic sweep above
    pass


# ---------------------------------------------------------------------------
# Device-mesh parity (child re-exec with 8 forced host devices)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def child_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve())],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_halo_value_parity_all_factorizations(child_results):
    keys = [k for k in child_results if k.startswith("val_")]
    assert keys, child_results
    for k in keys:
        assert child_results[k], k


def test_halo_gradient_parity_all_factorizations(child_results):
    keys = [k for k in child_results if k.startswith("grad_")]
    assert keys, child_results
    for k in keys:
        assert child_results[k], k


def _child_main():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.sharding import MeshPlan, host_mesh

    assert len(jax.devices()) == 8, jax.devices()
    results = {}
    R, d = 3, 5
    for ep in (2, 4, 8):
        mesh = host_mesh((ep, 8 // ep), ("ep", "other"))
        plan = MeshPlan(mesh=mesh, ep=ep, tp=1, dp_axes=("other",))
        xg = jax.random.normal(jax.random.PRNGKey(ep), (ep * ep, R, d))

        def run(fn):
            return jax.jit(compat.shard_map(
                fn, mesh=mesh, in_specs=P("ep", None, None),
                out_specs=P("ep", None, None), check_vma=False,
            ))(xg)

        def grad_of(fn):
            def loss(x):
                y = compat.shard_map(
                    fn, mesh=mesh, in_specs=P("ep", None, None),
                    out_specs=P("ep", None, None), check_vma=False,
                )(x)
                return jnp.sum(jnp.sin(y) * jnp.arange(y.size).reshape(y.shape))

            return jax.jit(jax.grad(loss))(xg)

        flat_v = run(halo.flat_all_to_all)
        flat_g = grad_of(halo.flat_all_to_all)
        # g1=None exercises the auto _pick_inner path; proper divisors the
        # explicit factorizations (ep=2 has none -> auto falls back to flat).
        g1s = [None] + [g for g in range(2, ep) if ep % g == 0]
        for g1 in g1s:
            fn = lambda xl, g=g1: halo.hierarchical_all_to_all(xl, plan, g1=g)
            tag = f"ep{ep}_g1{'auto' if g1 is None else g1}"
            results[f"val_{tag}"] = bool(np.allclose(
                np.asarray(flat_v), np.asarray(run(fn)), atol=1e-6))
            results[f"grad_{tag}"] = bool(np.allclose(
                np.asarray(flat_g), np.asarray(grad_of(fn)), atol=1e-6))
    print("RESULTS " + json.dumps(results))


if __name__ == "__main__":
    _child_main()
