"""Analytical comm model (paper Fig 5/8) + compression + data + checkpoint."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import comm_model as cm
from repro.core.compression import dequantize_int8, quantize_int8
from repro.core.platform import FRONTIER, TPU_V5E


def test_fig8_halo_beats_flat_at_scale():
    """Paper Fig 8: HALO achieves 1.1x-9x for >= 16 nodes; comparable below."""
    msg = 4 * 2**20  # 4 MiB rows
    speedups = {}
    for nodes in (1, 2, 4, 8, 16, 32, 64):
        case = cm.A2ACase(n_ranks=nodes * FRONTIER.chips_per_node, row_bytes=msg)
        speedups[nodes] = cm.speedup(case, FRONTIER)
    # large scale: within the paper's band
    assert 1.1 <= speedups[16] <= 9.5, speedups
    assert 1.1 <= speedups[64] <= 9.5, speedups
    # small scale: comparable (no huge win inside one switch group)
    assert speedups[1] == pytest.approx(1.0, abs=0.3)
    # monotone-ish growth into the inter-group regime
    assert speedups[64] >= speedups[8]


def test_fig5_bandwidth_knee():
    """Paper Fig 5: effective a2a bandwidth drops sharply once the group
    leaves a single node."""
    msg = 1 * 2**20
    bw_intra = cm.effective_a2a_bandwidth(
        cm.A2ACase(8, msg), FRONTIER, "flat"
    )
    bw_inter = cm.effective_a2a_bandwidth(
        cm.A2ACase(16, msg), FRONTIER, "flat"
    )
    assert bw_inter < 0.6 * bw_intra


def test_halo_time_components():
    case = cm.A2ACase(64, 2**20)
    t_flat = cm.flat_a2a_time(case, FRONTIER)
    t_halo = cm.halo_a2a_time(case, FRONTIER)
    assert 0 < t_halo <= t_flat


# -- compression -------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(10, 2000),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**16),
)
def test_int8_roundtrip_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, n), jnp.float32)
    q, s = quantize_int8(x, block=256)
    y = dequantize_int8(q, s, block=256, dtype=jnp.float32)
    err = np.abs(np.asarray(x) - np.asarray(y))
    # per-block bound: absmax/127 half-step
    blocks = np.asarray(jnp.pad(x, (0, (-n) % 256))).reshape(-1, 256)
    bound = np.abs(blocks).max(1) / 127.0
    for i in range(blocks.shape[0]):
        lo = i * 256
        hi = min(lo + 256, n)
        assert (err[lo:hi] <= bound[i] * 0.51 + 1e-7).all()


def test_ef_compression_residual_shrinks_error():
    from repro.core.compression import ef_compress

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=512), jnp.float32)
    q, s, resid = ef_compress(g, None)
    # the residual is exactly the quantization error
    approx = dequantize_int8(q, s, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(resid), np.asarray(g - approx), atol=1e-6
    )


# -- data pipeline ------------------------------------------------------------


def test_synthetic_stream_deterministic_and_sharded():
    from repro.data import SyntheticTokens

    a = SyntheticTokens(1000, 4, 16, shard_index=0, num_shards=2)
    b = SyntheticTokens(1000, 4, 16, shard_index=1, num_shards=2)
    a1 = a.batch_at(3)
    a2 = a.batch_at(3)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(a1["tokens"], b.batch_at(3)["tokens"])
    np.testing.assert_array_equal(
        a1["tokens"][:, 1:], a1["labels"][:, :-1]
    )


def test_memmap_corpus_roundtrip():
    from repro.data import MemmapCorpus, write_corpus

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "corpus.bin")
        toks = np.arange(10_000) % 777
        write_corpus(path, toks)
        ds = MemmapCorpus(path, batch=4, seq_len=32)
        b0 = ds.batch_at(0)
        assert b0["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
        # deterministic across instances
        ds2 = MemmapCorpus(path, batch=4, seq_len=32)
        np.testing.assert_array_equal(
            ds.batch_at(5)["tokens"], ds2.batch_at(5)["tokens"]
        )


# -- checkpointing -------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention():
    from repro.checkpoint import CheckpointManager, restore_checkpoint

    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4)},
        "step": jnp.int32(7),
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, every=1)
        for s in (1, 2, 3, 4):
            mgr.save(s, state, blocking=True)
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        restored, step = restore_checkpoint(d, abstract)
        assert step == 4
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(state["params"]["w"]),
        )
        # retention keeps only the last 2
        kept = sorted(p.name for p in __import__("pathlib").Path(d).iterdir())
        assert kept == ["step_00000003", "step_00000004"]


def test_trainer_resume_exact():
    """Kill-and-restart mid-run reproduces the uninterrupted run exactly
    (fault tolerance)."""
    from repro import training
    from repro.configs import get_arch
    from repro.data import SyntheticTokens
    from repro.models.model import LanguageModel
    from repro.optim import OptimizerConfig
    from repro.runtime import Trainer, TrainerConfig
    from repro.sharding import single_device_plan

    arch = get_arch("smollm-360m").reduced()
    plan = single_device_plan(arch)
    opt = OptimizerConfig(lr=1e-3)
    data = SyntheticTokens(arch.vocab_size, 2, 32)

    def loss_after(total, ckpt_dir, stop_at=None):
        with plan.mesh:
            lm = LanguageModel(arch, plan)
            state = training.init_state(lm, jax.random.PRNGKey(0), opt)
            tr = Trainer(
                lm, opt,
                TrainerConfig(
                    total_steps=stop_at or total,
                    checkpoint_dir=ckpt_dir,
                    checkpoint_every=5,
                    log_every=1000,
                ),
            )
            out = tr.fit(state, data)
            if stop_at:
                tr2 = Trainer(
                    lm, opt,
                    TrainerConfig(
                        total_steps=total,
                        checkpoint_dir=ckpt_dir,
                        checkpoint_every=5,
                        log_every=1000,
                    ),
                )
                state2 = training.init_state(lm, jax.random.PRNGKey(0), opt)
                out = tr2.fit(state2, data)
            return float(out["metrics"]["loss"])

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        uninterrupted = loss_after(15, d1)
        interrupted = loss_after(15, d2, stop_at=10)
        assert uninterrupted == pytest.approx(interrupted, abs=1e-5)
