"""Chaos suite: deterministic fault injection x recovery paths.

Every failure mode the runtime claims to survive is driven here through
``repro.runtime.faults`` and asserted to recover EXACTLY:

* injector determinism (same seed -> same plan; count-limited firing)
* async checkpoint write failure re-raises instead of vanishing
* crash before/after the atomic rename (previous ckpt survives / new one
  is complete), stale ``.tmp`` cleanup
* bit-flip corruption -> verify -> quarantine (never delete) -> fallback
* injected NaN -> skip-step sentinel -> rollback -> re-trained steps
  match the fault-free oracle bit-for-bit
* transient data errors retry with backoff; exhausted retries surface
* injected slow step trips the straggler monitor
* SIGTERM preemption + multi-device resume parity (subprocess, 8 devices)
"""

import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    checkpoint_steps,
    cleanup_stale_tmp,
    restore_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)
from repro.runtime.faults import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedWriteError,
    SimulatedCrash,
    TransientDataError,
)

CHILD = Path(__file__).with_name("_faults_child.py")


def quiet(_msg):
    pass


def _state(v=0.0):
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4) + v},
        "step": jnp.int32(7),
    }


def _abstract(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )


# -- injector ------------------------------------------------------------------


def test_fault_plan_seed_deterministic():
    a = FaultPlan.random(seed=7, total_steps=100)
    b = FaultPlan.random(seed=7, total_steps=100)
    # repr-compare: NaN payloads defeat dataclass == (nan != nan)
    assert repr(a.specs) == repr(b.specs) and len(a.specs) >= 1
    for spec in a.specs:
        assert 0 <= spec.step < 100


def test_injector_fires_count_then_exhausts():
    inj = FaultInjector(
        FaultPlan([FaultSpec("train.nonfinite", step=3, count=2,
                             payload=2.5)]),
        log_fn=quiet,
    )
    assert inj.payload_if("train.nonfinite", 2) is None  # not yet armed
    assert inj.payload_if("train.nonfinite", 3) == 2.5
    assert inj.payload_if("train.nonfinite", 4) == 2.5
    assert inj.payload_if("train.nonfinite", 5) is None  # exhausted
    assert inj.fired("train.nonfinite") == 2
    assert [r["step"] for r in inj.log] == [3, 4]


def test_injector_unknown_site_rejected():
    with pytest.raises(AssertionError):
        FaultSpec("not.a.site", step=0)


# -- checkpoint integrity ------------------------------------------------------


def test_async_write_failure_reraises_on_wait():
    """Satellite bug: a failed async write must re-raise on the next
    wait()/save(), not evaporate with the daemon thread."""
    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(
            FaultPlan([FaultSpec("ckpt.write_fail", step=1)]), log_fn=quiet
        )
        mgr = CheckpointManager(d, every=1, injector=inj, log_fn=quiet)
        mgr.save(1, _state(), blocking=False)
        with pytest.raises(InjectedWriteError):
            mgr.wait()
        # The error is surfaced once, then cleared: the manager keeps
        # working (spec exhausted -> this write succeeds).
        mgr.save(2, _state(), blocking=False)
        mgr.wait()
        assert checkpoint_steps(d) == [2]


def test_async_write_failure_reraises_on_next_save():
    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(
            FaultPlan([FaultSpec("ckpt.write_fail", step=1)]), log_fn=quiet
        )
        mgr = CheckpointManager(d, every=1, injector=inj, log_fn=quiet)
        mgr.save(1, _state(), blocking=False)
        with pytest.raises(InjectedWriteError):
            mgr.save(2, _state(), blocking=False)


def test_crash_before_rename_previous_survives():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state(1.0))
        inj = FaultInjector(
            FaultPlan([FaultSpec("ckpt.crash_before_rename", step=2)]),
            log_fn=quiet,
        )
        with pytest.raises(SimulatedCrash):
            save_checkpoint(d, 2, _state(2.0), injector=inj)
        # The half-written dir is a .tmp leftover, not a checkpoint.
        assert checkpoint_steps(d) == [1]
        assert (Path(d) / "step_00000002.tmp").exists()
        restored, step = restore_checkpoint(d, _abstract(_state()),
                                            log_fn=quiet)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(_state(1.0)["params"]["w"]),
        )
        removed = cleanup_stale_tmp(d)
        assert removed == ["step_00000002.tmp"]
        assert not (Path(d) / "step_00000002.tmp").exists()


def test_crash_after_rename_checkpoint_complete():
    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(
            FaultPlan([FaultSpec("ckpt.crash_after_rename", step=1)]),
            log_fn=quiet,
        )
        with pytest.raises(SimulatedCrash):
            save_checkpoint(d, 1, _state(1.0), injector=inj)
        # The rename happened first: the checkpoint is complete and valid.
        ok, reason = verify_checkpoint(Path(d) / "step_00000001")
        assert ok, reason
        _, step = restore_checkpoint(d, _abstract(_state()), log_fn=quiet)
        assert step == 1


def test_bitflip_quarantined_and_fallback():
    """Corrupted checkpoint: detected at restore, quarantined (never
    deleted), restore falls back to the newest intact one."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state(1.0))
        save_checkpoint(d, 2, _state(2.0))
        npz = Path(d) / "step_00000002" / "arrays.npz"
        blob = bytearray(npz.read_bytes())
        # Flip one byte of the actual array payload (npz is uncompressed,
        # so the raw leaf bytes appear verbatim in the zip).
        off = blob.find(
            np.asarray(_state(2.0)["params"]["w"]).tobytes()
        )
        assert off > 0
        blob[off] ^= 0xFF
        npz.write_bytes(bytes(blob))

        restored, step = restore_checkpoint(d, _abstract(_state()),
                                            log_fn=quiet)
        assert step == 1  # fell back
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(_state(1.0)["params"]["w"]),
        )
        names = sorted(p.name for p in Path(d).iterdir())
        # Quarantined, not deleted: the bad dir is still on disk.
        assert "step_00000001" in names
        assert any(n.startswith("step_00000002.corrupt") for n in names)
        assert "step_00000002" not in names
        corrupt = next(
            p for p in Path(d).iterdir()
            if p.name.startswith("step_00000002.corrupt")
        )
        assert (corrupt / "QUARANTINE_REASON").exists()
        # The quarantined dir is invisible to the step index.
        assert checkpoint_steps(d) == [1]


def test_explicit_corrupt_step_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state(1.0))
        save_checkpoint(d, 2, _state(2.0))
        (Path(d) / "step_00000002" / "manifest.crc32").write_text("12345")
        with pytest.raises(CheckpointCorruptError):
            restore_checkpoint(d, _abstract(_state()), step=2, log_fn=quiet)
        # Explicit request never silently restores something else — but
        # the corrupt dir was still quarantined for the postmortem.
        assert checkpoint_steps(d) == [1]


def test_truncated_manifest_detected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state(1.0))
        mf = Path(d) / "step_00000001" / "manifest.msgpack"
        mf.write_bytes(mf.read_bytes()[:-3])
        ok, reason = verify_checkpoint(Path(d) / "step_00000001")
        assert not ok and "digest" in reason


# -- trainer recovery ----------------------------------------------------------


def _trainer_env():
    from repro import training
    from repro.configs import get_arch
    from repro.data import SyntheticTokens
    from repro.models.model import LanguageModel
    from repro.optim import OptimizerConfig
    from repro.sharding import single_device_plan

    arch = get_arch("smollm-360m").reduced()
    plan = single_device_plan(arch)
    opt = OptimizerConfig(lr=1e-3)
    data = SyntheticTokens(arch.vocab_size, 2, 32)
    return arch, plan, opt, data, training, LanguageModel


def _run_trainer(total, ckpt_dir, injector=None, **cfg_kw):
    from repro.runtime import Trainer, TrainerConfig

    arch, plan, opt, data, training, LanguageModel = _trainer_env()
    with plan.mesh:
        lm = LanguageModel(arch, plan)
        state = training.init_state(lm, jax.random.PRNGKey(0), opt)
        tr = Trainer(
            lm, opt,
            TrainerConfig(
                total_steps=total, checkpoint_dir=ckpt_dir,
                checkpoint_every=4, log_every=1000, **cfg_kw,
            ),
            log_fn=quiet, injector=injector,
        )
        out = tr.fit(state, data)
    return out


def test_nan_rollback_matches_fault_free_oracle():
    """Injected NaN x3 -> skip-steps -> rollback to last good ckpt ->
    re-trained steps reproduce the fault-free trajectory bit-for-bit
    (count-limited spec does not re-fire after rollback)."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        oracle = _run_trainer(12, d1)
        inj = FaultInjector(
            FaultPlan([FaultSpec("train.nonfinite", step=6, count=3)]),
            log_fn=quiet,
        )
        out = _run_trainer(12, d2, injector=inj, anomaly_rollback_after=3)
        assert inj.fired("train.nonfinite") == 3
        assert [a["step"] for a in out["anomalies"]] == [6, 7, 8]
        assert all(not np.isfinite(a["loss"]) for a in out["anomalies"])
        assert out["rollbacks"] == [{"at_step": 8, "to_step": 4}]
        assert float(out["metrics"]["loss"]) == float(
            oracle["metrics"]["loss"]
        )


def test_rollback_without_checkpoint_raises():
    inj = FaultInjector(
        FaultPlan([FaultSpec("train.nonfinite", step=2, count=3)]),
        log_fn=quiet,
    )
    with pytest.raises(RuntimeError, match="no checkpoint_dir"):
        _run_trainer(8, None, injector=inj, anomaly_rollback_after=3)


def test_rollback_budget_exhausts():
    """Anomalies that persist past the rollback budget surface instead of
    looping forever."""
    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(
            FaultPlan([FaultSpec("train.nonfinite", step=5, count=100)]),
            log_fn=quiet,
        )
        with pytest.raises(RuntimeError, match="budget exhausted"):
            _run_trainer(
                12, d, injector=inj, anomaly_rollback_after=2,
                max_rollbacks=2,
            )


def test_data_transient_retry_recovers():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        oracle = _run_trainer(6, d1)
        inj = FaultInjector(
            FaultPlan([FaultSpec("data.transient", step=2, count=2)]),
            log_fn=quiet,
        )
        out = _run_trainer(6, d2, injector=inj, data_backoff_s=0.001)
        assert inj.fired("data.transient") == 2
        assert float(out["metrics"]["loss"]) == float(
            oracle["metrics"]["loss"]
        )


def test_data_transient_exhausted_retries_surface():
    with tempfile.TemporaryDirectory() as d:
        inj = FaultInjector(
            FaultPlan([FaultSpec("data.transient", step=2, count=50)]),
            log_fn=quiet,
        )
        with pytest.raises(TransientDataError):
            _run_trainer(
                6, d, injector=inj, data_retries=2, data_backoff_s=0.001
            )


def test_slow_step_trips_straggler_monitor():
    with tempfile.TemporaryDirectory() as d:
        # Inject late enough that the EMA window has washed out the jit
        # compile time of step 0 (window = last 19 step times).
        inj = FaultInjector(
            FaultPlan([FaultSpec("train.slow_step", step=25, payload=0.5)]),
            log_fn=quiet,
        )
        out = _run_trainer(28, d, injector=inj)
        assert inj.fired("train.slow_step") == 1
        assert 25 in out["stragglers"]


# -- subprocess chaos (SIGTERM + multi-device resume) --------------------------


@pytest.fixture(scope="module")
def child_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(CHILD)],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_sigterm_preemption_resume_bitexact(child_results):
    assert child_results["sigterm_fired"]
    assert child_results["sigterm_stopped_early"]
    assert child_results["sigterm_resume_bitexact"]


def test_multidevice_resume_sharding_parity(child_results):
    """Satellite bug: restore must thread the live state's shardings —
    restored leaves land sharded per the plan, not replicated."""
    assert child_results["resume_ckpt_step"]
    assert child_results["resume_any_leaf_sharded"]
    assert child_results["resume_shardings_match"]
    assert child_results["resume_loss_match"]


def test_load_stats_survive_sigterm_bitexact(child_results):
    """The router-load EMA rides the checkpoint extras: a SIGTERM restart
    restores it byte-for-byte (raw float64 bytes, no device round-trip)
    and the resumed run's final EMA matches the uninterrupted oracle."""
    assert child_results["load_stats_saved_nonzero"]
    assert child_results["load_stats_restore_bitexact"]
    assert child_results["load_stats_resume_matches_oracle"]
