"""Serving subsystem tests (single device, every container).

* paged KV-cache invariants: allocator lifecycle (admit/extend/release,
  LIFO block reuse, exhaustion), append/gather roundtrip, pad masking,
  sentinel-slot isolation, prefix-gather == dense attention;
* decode parity: paged prefill + decode steps against the uncached
  forward to 1e-5 for BOTH dispatch modes (capacity at a no-drop cf;
  ragged is dropless by construction);
* engine scheduler: deterministic trace, FIFO admission, no starvation,
  preemption-transparent outputs;
* decode metric sanity: the replicated-token metric reduction matches the
  collective-free oracle at ep=1 (the ep>1 invariance lives in
  tests/test_serving_multidevice.py).
"""

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import kv_cache as kvlib
from repro.serving.engine import Engine, Request, ServeConfig
from repro.serving.kv_cache import BlockPool, PagedLayout


# ---------------------------------------------------------------------------
# BlockPool (host allocator)
# ---------------------------------------------------------------------------


def test_block_pool_lifecycle_and_reuse():
    layout = PagedLayout(num_blocks=8, block_size=4, max_seqs=3,
                         max_blocks_per_seq=4)
    pool = BlockPool(layout)
    s0 = pool.admit(5)  # 2 pages
    s1 = pool.admit(4)  # 1 page
    pool.check_invariants()
    assert pool.free_blocks == 5
    # extend across a page boundary allocates exactly one page
    assert pool.extend(s1, 1)
    assert pool.free_blocks == 4
    pool.check_invariants()
    # release returns pages; the NEXT admit reuses them (LIFO) — stale
    # pages must be fully re-owned, never shared
    released = [p for p in pool.block_table[s0] if p != layout.sentinel]
    pool.release(s0)
    assert pool.free_blocks == 6
    s2 = pool.admit(8)  # 2 pages — reuses the just-released ones
    got = [p for p in pool.block_table[s2] if p != layout.sentinel]
    assert set(got) & set(released), "LIFO reuse expected"
    pool.check_invariants()


def test_block_pool_exhaustion_and_slots():
    layout = PagedLayout(num_blocks=4, block_size=4, max_seqs=2,
                         max_blocks_per_seq=4)
    pool = BlockPool(layout)
    pool.admit(8)
    pool.admit(8)
    assert pool.free_slot() is None
    assert not pool.can_admit(1, 1)  # no slot
    assert not pool.extend(0, 8)  # pool exhausted mid-decode
    pool.release(1)
    assert pool.can_admit(4, 4)
    # over-long requests are rejected up front
    assert not pool.can_admit(layout.max_len + 1, 0)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Device ops
# ---------------------------------------------------------------------------


def test_append_gather_roundtrip():
    layout = PagedLayout(num_blocks=6, block_size=4, max_seqs=2,
                         max_blocks_per_seq=3)
    h, d = 2, 8
    pages = jnp.zeros((layout.num_blocks, layout.block_size, h, d))
    # two sequences on non-contiguous, interleaved pages
    bt = jnp.asarray([[3, 0, 6], [5, 1, 6]], jnp.int32)  # 6 = sentinel
    kv = jax.random.normal(jax.random.PRNGKey(0), (2, 7, h, d))
    lens = jnp.asarray([7, 5], jnp.int32)
    pages = kvlib.append_tokens(
        pages, bt, jnp.zeros((2,), jnp.int32), kv, count=lens
    )
    dense = kvlib.gather_pages(pages, bt)  # (2, 12, h, d)
    np.testing.assert_allclose(np.asarray(dense[0, :7]), np.asarray(kv[0]))
    np.testing.assert_allclose(np.asarray(dense[1, :5]), np.asarray(kv[1, :5]))
    # pad rows (beyond count) were never written
    assert float(jnp.abs(dense[1, 5:8]).max()) == 0.0
    # sentinel pages read as zeros
    assert float(jnp.abs(dense[:, 8:]).max()) == 0.0
    # incremental append at an offset lands at the right position
    tok = jax.random.normal(jax.random.PRNGKey(1), (2, 1, h, d))
    pages = kvlib.append_tokens(pages, bt, lens, tok)
    dense2 = kvlib.gather_pages(pages, bt)
    np.testing.assert_allclose(np.asarray(dense2[0, 7]), np.asarray(tok[0, 0]))
    np.testing.assert_allclose(np.asarray(dense2[1, 5]), np.asarray(tok[1, 0]))


def test_sentinel_rows_do_not_corrupt_pool():
    """Inactive batch slots (all-sentinel block-table rows) must drop their
    writes instead of clobbering live pages."""
    layout = PagedLayout(num_blocks=2, block_size=2, max_seqs=2,
                         max_blocks_per_seq=1)
    pages = jnp.ones((2, 2, 1, 4))
    bt = jnp.asarray([[0], [2]], jnp.int32)  # slot 1 inactive (sentinel)
    kv = jnp.full((2, 1, 1, 4), 7.0)
    out = kvlib.append_tokens(pages, bt, jnp.zeros((2,), jnp.int32), kv)
    np.testing.assert_allclose(np.asarray(out[0, 0]), 7.0)  # slot 0 wrote
    np.testing.assert_allclose(np.asarray(out[1]), 1.0)  # untouched


def test_prefix_gather_equals_dense_attention():
    """Attention over the paged prefix view (scattered pages + kv_len
    masking) equals attention over the dense K/V prefix."""
    from repro.models import layers as L

    layout = PagedLayout(num_blocks=8, block_size=4, max_seqs=2,
                         max_blocks_per_seq=4)
    h, d = 2, 16
    lens = np.asarray([11, 6])
    rng = jax.random.PRNGKey(2)
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (2, 1, 4, d))
    k_dense = jax.random.normal(kk, (2, 16, h, d))
    v_dense = jax.random.normal(kv_, (2, 16, h, d))
    pool = BlockPool(layout)
    pool.admit(int(lens[0]))
    pool.admit(int(lens[1]))
    bt = jnp.asarray(pool.block_table)
    pages_k = jnp.zeros((layout.num_blocks, layout.block_size, h, d))
    pages_v = jnp.zeros_like(pages_k)
    pages_k = kvlib.append_tokens(
        pages_k, bt, jnp.zeros((2,), jnp.int32), k_dense,
        count=jnp.asarray(lens),
    )
    pages_v = kvlib.append_tokens(
        pages_v, bt, jnp.zeros((2,), jnp.int32), v_dense,
        count=jnp.asarray(lens),
    )
    ck = kvlib.gather_pages(pages_k, bt)
    cv = kvlib.gather_pages(pages_v, bt)
    out_paged = L.attention(
        q, ck, cv, q_offset=jnp.asarray(lens - 1), kv_len=jnp.asarray(lens)
    )
    for i, n in enumerate(lens):
        ref = L.attention(
            q[i:i + 1], k_dense[i:i + 1, :n], v_dense[i:i + 1, :n],
            q_offset=int(n) - 1,
        )
        np.testing.assert_allclose(
            np.asarray(out_paged[i]), np.asarray(ref[0]), atol=1e-5
        )


# ---------------------------------------------------------------------------
# Decode parity vs the uncached forward
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def serving_setup(dispatch: str):
    from repro.configs import get_arch
    from repro.models.model import LanguageModel, init_params
    from repro.sharding import single_device_plan

    arch = get_arch("granite-moe-3b-a800m").reduced()
    E, k = arch.moe.num_experts, arch.moe.top_k
    # capacity at a provably-no-drop cf so both modes admit exact parity
    arch = arch.replace(
        moe=dataclasses.replace(
            arch.moe, dispatch=dispatch, capacity_factor=float(E) / k + 1.0
        )
    )
    plan = single_device_plan(arch)
    lm = LanguageModel(arch, plan)
    with plan.mesh:
        params = init_params(arch, jax.random.PRNGKey(0))
    return arch, plan, lm, params


@pytest.mark.parametrize("dispatch", ["capacity", "ragged"])
def test_decode_parity_vs_uncached_forward(dispatch):
    """Paged prefill + per-step decode logits == the no-cache forward's
    logits at the matching positions, to 1e-5, for both dispatch modes."""
    arch, plan, lm, params = serving_setup(dispatch)
    layout = PagedLayout(num_blocks=16, block_size=4, max_seqs=1,
                         max_blocks_per_seq=8)
    rng = np.random.default_rng(3)
    seq = rng.integers(0, arch.vocab_size, size=14).astype(np.int32)
    plen, steps = 9, 5
    pool = BlockPool(layout)
    slot = pool.admit(plen)
    with plan.mesh:
        cache = lm.init_paged_cache(layout, dtype=jnp.float32)
        logits, cache = jax.jit(lm.prefill_paged)(
            params, {"tokens": jnp.asarray(seq[None, :plen])}, cache,
            jnp.asarray(pool.block_table[slot][None]),
            jnp.asarray([plen], jnp.int32),
        )
        ref, _, _ = jax.jit(lm.forward)(
            params, {"tokens": jnp.asarray(seq[None])}
        )
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(ref[0, plen - 1]), atol=1e-5
        )
        decode = jax.jit(lm.decode_step_paged)
        for i in range(steps):
            pool.extend(slot, 1)
            logits, cache = decode(
                params, cache,
                jnp.asarray(pool.block_table[slot][None]),
                jnp.asarray([plen + i], jnp.int32),
                {"tokens": jnp.asarray(seq[None, plen + i:plen + i + 1])},
            )
            np.testing.assert_allclose(
                np.asarray(logits[0]), np.asarray(ref[0, plen + i]),
                atol=1e-5, err_msg=f"{dispatch} step {i}",
            )


def test_capacity_and_ragged_decode_agree():
    """At a no-drop capacity factor the two dispatch modes are the same
    math: per-step decode logits agree to 1e-5."""
    _, plan_c, lm_c, params = serving_setup("capacity")
    arch_r, _, lm_r, _ = serving_setup("ragged")
    layout = PagedLayout(num_blocks=8, block_size=4, max_seqs=2,
                         max_blocks_per_seq=4)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, arch_r.vocab_size, size=(2, 6)).astype(np.int32)
    pool = BlockPool(layout)
    pool.admit(6)
    pool.admit(6)
    bt = jnp.asarray(pool.block_table)
    lens = jnp.asarray(pool.lengths)
    with plan_c.mesh:
        outs = {}
        for name, lm in (("capacity", lm_c), ("ragged", lm_r)):
            cache = lm.init_paged_cache(layout, dtype=jnp.float32)
            _, cache = jax.jit(lm.prefill_paged)(
                params, {"tokens": jnp.asarray(toks)}, cache, bt, lens
            )
            logits, _ = jax.jit(lm.decode_step_paged)(
                params, cache, bt, lens,
                {"tokens": jnp.asarray(toks[:, :1])},
            )
            outs[name] = np.asarray(logits)
    np.testing.assert_allclose(outs["capacity"], outs["ragged"], atol=1e-5)


# ---------------------------------------------------------------------------
# Engine scheduler
# ---------------------------------------------------------------------------


def _requests(arch, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 14, size=n)
    return [
        Request(rid=i, tokens=rng.integers(0, arch.vocab_size, size=int(l)),
                max_new_tokens=max_new)
        for i, l in enumerate(lens)
    ]


def _run_engine(dispatch, cfg, n=6, seed=0, max_new=4):
    arch, plan, lm, params = serving_setup(dispatch)
    with plan.mesh:
        eng = Engine(lm, params, cfg)
        out = eng.run(_requests(arch, n, seed, max_new))
    return eng, out


def test_engine_trace_deterministic_fifo_no_starvation():
    cfg = ServeConfig(max_seqs=2, block_size=4, num_blocks=32,
                      max_blocks_per_seq=8)
    eng1, out1 = _run_engine("ragged", cfg)
    eng2, out2 = _run_engine("ragged", cfg)
    # deterministic: identical trace and outputs across runs
    assert eng1.trace == eng2.trace
    assert out1 == out2
    # no starvation: every submitted request finished with its full budget
    assert sorted(out1) == list(range(6))
    assert all(len(v) == 4 for v in out1.values())
    # FIFO admission: admit events in submission order
    admits = [e[2] for e in eng1.trace if e[0] == "admit"]
    assert admits == sorted(admits) == list(range(6))
    # iteration-level batching: some decode step ran >1 sequence together,
    # and sequences admitted at different steps shared a decode batch
    decode_rids = [set(e[2]) for e in eng1.trace if e[0] == "decode"]
    assert any(len(s) > 1 for s in decode_rids)
    # the batch composition changes over time (continuous, not static)
    assert len({frozenset(s) for s in decode_rids}) > 1
    eng1.pool.check_invariants()
    assert eng1.pool.free_blocks == cfg.num_blocks  # everything released


def test_engine_overbudget_prompt_still_admits():
    """A prompt longer than the per-step prefill token budget (possible
    after preemption merges generated tokens into the prompt) must still
    be admitted — alone, on a fresh step — never wedge the FIFO head."""
    arch, plan, lm, params = serving_setup("ragged")
    cfg = ServeConfig(max_seqs=2, block_size=4, num_blocks=32,
                      max_blocks_per_seq=8, prefill_tokens_per_step=8)
    rng = np.random.default_rng(2)
    reqs = [
        Request(rid=0, tokens=rng.integers(0, arch.vocab_size, size=13),
                max_new_tokens=3),  # > 8-token budget
        Request(rid=1, tokens=rng.integers(0, arch.vocab_size, size=4),
                max_new_tokens=3),
    ]
    with plan.mesh:
        eng = Engine(lm, params, cfg)
        out = eng.run(reqs)
    assert sorted(out) == [0, 1] and all(len(v) == 3 for v in out.values())
    # un-servable requests are rejected up front, not queued forever
    with pytest.raises(AssertionError):
        eng.submit(Request(rid=9, tokens=np.zeros(40, np.int32),
                           max_new_tokens=1))


def test_engine_preemption_transparent():
    """A pool too small for all admitted sequences forces preemption; the
    preempted request is re-prefilled (prompt + generated) and must emit
    exactly the tokens of an unconstrained run — paged decode is exact, so
    eviction is invisible in outputs."""
    roomy = ServeConfig(max_seqs=2, block_size=4, num_blocks=64,
                        max_blocks_per_seq=8)
    tight = ServeConfig(max_seqs=2, block_size=4, num_blocks=7,
                        max_blocks_per_seq=8)
    _, out_roomy = _run_engine("ragged", roomy, n=3, seed=1, max_new=6)
    eng, out_tight = _run_engine("ragged", tight, n=3, seed=1, max_new=6)
    assert sorted(out_tight) == [0, 1, 2]
    assert out_tight == out_roomy
    assert any(e[0] == "preempt" for e in eng.trace), (
        "tight pool was expected to preempt"
    )
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Graceful degradation: deadlines, stalls, backpressure
# ---------------------------------------------------------------------------


def test_engine_deadline_shed_and_met():
    """An infeasible deadline is shed with a structured abort record; a
    feasible one completes untouched.  Shed ≠ deleted: the abort carries
    rid, step, reason and any partial tokens."""
    arch, plan, lm, params = serving_setup("ragged")
    cfg = ServeConfig(max_seqs=2, block_size=4, num_blocks=32,
                      max_blocks_per_seq=8)
    rng = np.random.default_rng(7)
    reqs = [
        Request(rid=0, tokens=rng.integers(0, arch.vocab_size, size=5),
                max_new_tokens=4, deadline_step=10),  # feasible
        Request(rid=1, tokens=rng.integers(0, arch.vocab_size, size=5),
                max_new_tokens=6, deadline_step=2),  # provably infeasible
    ]
    with plan.mesh:
        eng = Engine(lm, params, cfg)
        out = eng.run(reqs)
    assert sorted(out) == [0] and len(out[0]) == 4
    assert 1 in eng.aborted
    ab = eng.aborted[1]
    assert ab.reason == "deadline" and ab.generated == []
    assert ("abort", ab.step, 1, "deadline") in eng.trace
    # a no-deadline engine run is untouched by the feature (default None)
    assert eng.backpressure_steps == 0


def test_engine_stall_burns_deadline_running_shed():
    """Injected scheduler stalls burn a running request's deadline budget;
    once infeasible it is shed mid-flight with its partial tokens."""
    from repro.runtime.faults import FaultInjector, FaultPlan, FaultSpec

    arch, plan, lm, params = serving_setup("ragged")
    cfg = ServeConfig(max_seqs=2, block_size=4, num_blocks=32,
                      max_blocks_per_seq=8)
    rng = np.random.default_rng(8)
    req = Request(rid=0, tokens=rng.integers(0, arch.vocab_size, size=5),
                  max_new_tokens=5, deadline_step=6)  # feasible un-stalled
    inj = FaultInjector(
        FaultPlan([FaultSpec("serve.stall", step=2, count=3)]),
        log_fn=lambda m: None,
    )
    with plan.mesh:
        eng = Engine(lm, params, cfg, injector=inj)
        out = eng.run([req])
    assert out == {}  # never finished
    assert inj.fired("serve.stall") == 3
    assert [e for e in eng.trace if e[0] == "stall"] == [
        ("stall", 2), ("stall", 3), ("stall", 4)
    ]
    ab = eng.aborted[0]
    assert ab.reason == "deadline"
    # prefill+decode at step 1 produced 2 tokens before the stalls
    assert len(ab.generated) == 2
    eng.pool.check_invariants()
    assert eng.pool.free_blocks == cfg.num_blocks


def test_engine_backpressure_defers_admission():
    """With admit_reserve_blocks the tight pool holds new work in the
    queue instead of admitting into certain preemption churn — outputs
    still match the unconstrained run (default 0 keeps pure FIFO-fit)."""
    roomy = ServeConfig(max_seqs=2, block_size=4, num_blocks=64,
                        max_blocks_per_seq=8)
    tight_bp = ServeConfig(max_seqs=2, block_size=4, num_blocks=7,
                           max_blocks_per_seq=8, admit_reserve_blocks=2)
    _, out_roomy = _run_engine("ragged", roomy, n=3, seed=1, max_new=6)
    eng, out_bp = _run_engine("ragged", tight_bp, n=3, seed=1, max_new=6)
    assert eng.backpressure_steps > 0
    assert out_bp == out_roomy
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Decode metric sanity (ep=1; the ep>1 invariance is multidevice)
# ---------------------------------------------------------------------------


def test_decode_metrics_match_local_oracle():
    from repro.models import moe as moe_lib

    arch, plan, lm, params = serving_setup("ragged")
    ffn = jax.tree.map(lambda p: p[0], params["blocks"][0]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 4, arch.d_model))
    with plan.mesh:
        _, m_dec = moe_lib.moe_ffn(ffn, x, arch, plan, token_sharded=False)
        _, m_loc = moe_lib.moe_ffn_local(ffn, x, arch)
    for k in ("moe_aux_loss", "moe_z_loss", "expert_load"):
        np.testing.assert_allclose(
            np.asarray(m_dec[k]), np.asarray(m_loc[k]), atol=1e-6, err_msg=k
        )
