"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/dryrun/*.json.  Hand-written narrative lives in
docs/experiments_*.md fragments; this script stitches everything together.

    PYTHONPATH=src python scripts/gen_experiments.py
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch import roofline as RL

ROOT = Path(__file__).resolve().parents[1]


def dryrun_section(records):
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture × input shape) cell is lowered + compiled with "
        "`jax.jit(step, in_shardings=..., out_shardings=..., donate_argnums=...)"
        ".lower(...).compile()` against the production meshes "
        "(single-pod 16×16 → (\"data\",\"model\"), refined per-arch into "
        "(\"data\",\"ep\",\"tp\"); multi-pod 2×16×16 adds the \"pod\" axis). "
        "ShapeDtypeStruct stand-ins — no device allocation. "
        "`compiled.memory_analysis()` / loop-aware HLO analysis per cell in "
        "`results/dryrun/*.json`. "
        "Records generated in the CPU container are HOST-lowered: XLA:CPU "
        "ignores the TPU memory model, so per-device byte/time columns are "
        "structural only (expect absurd absolute values) — regenerate on "
        "the target platform for real numbers.",
        "",
    ]
    ok = [r for r in records.values() if r["status"] == "ok"]
    sk = [r for r in records.values() if r["status"] == "skipped"]
    er = [r for r in records.values() if r["status"] == "error"]
    lines.append(
        f"**Matrix status: {len(ok)} compiled OK, {len(sk)} skipped "
        f"(long_500k × full-attention archs, per DESIGN.md), "
        f"{len(er)} errors.**"
    )
    lines.append("")
    hdr = (
        f"| cell | chips | ep×tp×pp | mem/dev GB | HLO GFLOPs/dev | "
        f"wire GB/dev | collectives (count) |"
    )
    lines += [hdr, "|" + "---|" * 7]
    for cell, r in sorted(records.items()):
        if r["status"] == "skipped":
            lines.append(f"| {cell} | — | — | — | — | — | skipped: {r['reason'][:40]} |")
            continue
        if r["status"] == "error":
            lines.append(f"| {cell} | — | — | — | — | — | ERROR: {r['error'][:60]} |")
            continue
        ca = r["cost_analysis"]
        co = r["collectives"]
        counts = ", ".join(
            f"{k.replace('all-','a-').replace('collective-','c-')}:{int(v)}"
            for k, v in sorted(co["counts"].items())
        )
        lines.append(
            f"| {cell} | {r['chips']} | {r['ep']}×{r['tp']}×{r['pp']} | "
            f"{r['memory_analysis']['peak_bytes_per_device']/1e9:.2f} | "
            f"{ca['flops']/1e9:,.0f} | {co['total_wire_bytes']/1e9:.1f} | "
            f"{counts} |"
        )
    lines.append("")
    return "\n".join(lines)


def roofline_section(records):
    lines = [
        "## §Roofline",
        "",
        "Per-cell three-term roofline (single-pod, TPU v5e constants: "
        "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI).  "
        "`compute = HLO_FLOPs/dev ÷ peak`; `memory = HLO_bytes/dev ÷ BW` "
        "(≥1 MiB ops; loop-aware); `collective = wire_bytes/dev ÷ link_bw` "
        "(ring/linear models per op, loop-aware).  `useful` = "
        "MODEL_FLOPS / (HLO_FLOPs × chips) with MODEL_FLOPS = 6·N_active·D "
        "(train) or 2·N_active·D (serve); `roofMFU` = useful model FLOP/s at "
        "the binding term, as a fraction of peak — the roofline fraction.",
        "",
        "```",
        RL.table(records, multi_pod=False),
        "```",
        "",
        "Multi-pod (2×16×16; pod axis = DP for the baseline, PP for the "
        "`-pp` Piper cells):",
        "",
        "```",
        RL.table(records, multi_pod=True),
        "```",
        "",
    ]
    return "\n".join(lines)


def main():
    records = RL.load_records()
    frame = (ROOT / "docs" / "experiments_frame.md").read_text()
    perf = (ROOT / "docs" / "experiments_perf.md").read_text()
    serving = (ROOT / "docs" / "experiments_serving.md").read_text()
    schedules = (ROOT / "docs" / "experiments_schedules.md").read_text()
    a2a = (ROOT / "docs" / "experiments_a2a.md").read_text()
    robustness = (ROOT / "docs" / "experiments_robustness.md").read_text()
    migration = (ROOT / "docs" / "experiments_migration.md").read_text()
    observability = (ROOT / "docs" / "experiments_obs.md").read_text()
    out = frame.format(
        dryrun=dryrun_section(records),
        roofline=roofline_section(records),
        serving=serving,
        schedules=schedules,
        a2a=a2a,
        robustness=robustness,
        migration=migration,
        observability=observability,
        perf=perf,
    )
    (ROOT / "EXPERIMENTS.md").write_text(out)
    print(f"EXPERIMENTS.md regenerated ({len(records)} cells)")


if __name__ == "__main__":
    main()
