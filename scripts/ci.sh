#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md).
#
# XLA_FLAGS forces 8 host CPU devices so the multidevice suites
# (tests/test_multidevice.py, tests/test_pipeline_schedules.py) exercise
# real meshes: EP all-to-all, HALO, and the schedule-driven pipeline
# executor over a 2- and 4-stage "pod" axis.  The multidevice tests
# re-exec themselves in a subprocess with the same flag, so this also works
# when the parent pytest was started without it — exporting it here just
# keeps single- and multi-process behavior identical.
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Kernel-focused stage: the Pallas kernels (interpret mode on CPU) and the
# MoE dispatch property suite, run first so a kernel regression fails fast.
python -m pytest tests/test_kernels.py tests/test_moe_dispatch.py \
    tests/test_moe_properties.py -q

# Serving smoke stage: the continuous-batching engine + paged KV-cache +
# ragged decode parity suite (fast, single-device).
python -m pytest tests/test_serving.py -q

# Bench schema-rot gates: the smoke benches must still emit the exact key
# structure of the committed BENCH_*.json files (regenerate + commit them
# whenever a bench schema intentionally changes).
python benchmarks/moe_gemm_bench.py --smoke --check-schema BENCH_moe_gemm.json
python benchmarks/schedule_bench.py --smoke --check-schema BENCH_schedules.json
python benchmarks/serving_bench.py --smoke --check-schema BENCH_serving.json

exec python -m pytest -x -q "$@"
