#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md).
#
# XLA_FLAGS forces 8 host CPU devices so the multidevice suites
# (tests/test_multidevice.py, tests/test_pipeline_schedules.py) exercise
# real meshes: EP all-to-all, HALO, and the schedule-driven pipeline
# executor over a 2- and 4-stage "pod" axis.  The multidevice tests
# re-exec themselves in a subprocess with the same flag, so this also works
# when the parent pytest was started without it — exporting it here just
# keeps single- and multi-process behavior identical.
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Kernel-focused stage: the Pallas kernels (interpret mode on CPU) and the
# MoE dispatch property suite, run first so a kernel regression fails fast.
python -m pytest tests/test_kernels.py tests/test_moe_dispatch.py \
    tests/test_moe_properties.py -q

# Serving smoke stage: the continuous-batching engine + paged KV-cache +
# ragged decode parity suite (fast, single-device).
python -m pytest tests/test_serving.py -q

# Chaos stage: deterministic fault injection end to end — corrupt-checkpoint
# quarantine/fallback, crash-mid-save, NaN skip->rollback oracle match, and
# the subprocess SIGTERM-resume + multidevice resume-parity children.
python -m pytest tests/test_faults.py -q

# Bench schema-rot gates: the smoke benches must still emit the exact key
# structure of the committed BENCH_*.json files (regenerate + commit them
# whenever a bench schema intentionally changes).
python benchmarks/moe_gemm_bench.py --smoke --check-schema BENCH_moe_gemm.json
python benchmarks/schedule_bench.py --smoke --check-schema BENCH_schedules.json
python benchmarks/serving_bench.py --smoke --check-schema BENCH_serving.json
python benchmarks/a2a_overlap_bench.py --smoke --check-schema BENCH_a2a_overlap.json
python benchmarks/robustness_bench.py --smoke --check-schema BENCH_robustness.json
python benchmarks/migration_bench.py --smoke --check-schema BENCH_migration.json
python benchmarks/obs_bench.py --smoke --check-schema BENCH_observability.json

# Zero-bubble acceptance gate on the committed schedule bench: zb_h1 rows
# exist, beat 1f1b's bubble at EQUAL Eq-4 residual-slot count on every
# (PP, M) cell, and report their W-stash separately.
python - <<'PY'
import json
rec = json.load(open("BENCH_schedules.json"))
zb = [s for s in rec["sweep"] if s["schedule"] == "zb_h1"]
assert zb, "BENCH_schedules.json has no zb_h1 rows -- regenerate it"
assert rec["summary"]["zb_equal_slots"] is True, (
    "zb_h1 must beat 1f1b at equal residual slots on every cell")
assert all(s["num_wslots"] > 0 and s["wstash_bytes_ref"] > 0 for s in zb), (
    "zb_h1 rows must report their W-stash (slots + bytes)")
print(f"zb gate ok: {len(zb)} zb_h1 cells, equal-slot bubble win on all")
PY

# Comm-lane overlap acceptance gate on the committed schedule bench:
# 1f1b_overlap rows exist and, against the non-overlap 1f1b twin of the
# SAME (PP, M) cell, keep the identical compute account (makespan,
# residual slots, bubble) while strictly reducing the modeled exposed p2p
# on EVERY cell (and never losing the a2a bracket comparison).
python - <<'PY'
import json
rec = json.load(open("BENCH_schedules.json"))
ov = [s for s in rec["sweep"] if s["schedule"] == "1f1b_overlap"]
assert ov, "BENCH_schedules.json has no 1f1b_overlap rows -- regenerate it"
s = rec["summary"]
assert s["overlap_same_compute_all"] is True, (
    "1f1b_overlap must keep 1f1b's makespan/slots/bubble on every cell")
assert s["overlap_exposed_p2p_win_all"] is True, (
    "1f1b_overlap must strictly reduce exposed p2p vs 1f1b on every cell")
assert s["overlap_exposed_a2a_win_all"] is True, (
    "1f1b_overlap must never lose the exposed-a2a comparison")
assert all(x["num_cslots"] >= 1 for x in ov), (
    "overlap rows must report their in-flight comm-slot pool")
print(f"overlap gate ok: {len(ov)} cells, strict exposed-p2p win on all "
      f"(max shrink {s['overlap_p2p_shrink_max']:.2f}x, "
      f"<= {s['overlap_cslots_max']} comm slots)")
PY

# Chunked-a2a acceptance gate on the committed overlap bench: the best
# chunked K strictly beats the monolithic K=1 layer pass on at least one
# multi-device cell, and the calibrated comm-model's argmax-K direction
# agrees with the measured one on the headline cell.
python - <<'PY'
import json
rec = json.load(open("BENCH_a2a_overlap.json"))
s = rec["summary"]
assert rec["sweep"], "BENCH_a2a_overlap.json has no cells -- regenerate it"
assert s["chunked_beats_monolithic"] is True, (
    "chunked double-buffered a2a must beat monolithic K=1 on >= 1 cell")
assert s["model_direction_agrees"] is True, (
    "calibrated model argmax-K direction must match the measured one")
h = s["headline"]
print(f"a2a overlap gate ok: ep={h['ep']} {h['algo']} "
      f"K={h['best_measured_K']} -> {h['speedup_best_vs_K1']:.2f}x vs K=1 "
      f"({s['cells_with_chunked_win']}/{len(rec['sweep'])} cells win)")
PY

# Robustness acceptance gate on the committed bench: every recovery drill
# recovered, the fitted write model predicts the interior sweep point
# within 2x, and the resource model prices the Young-Daly cadence.
python - <<'PY'
import json
rec = json.load(open("BENCH_robustness.json"))
s = rec["summary"]
assert s["all_recovered"] is True, (
    "every fault-class recovery drill must recover -- regenerate the bench")
assert s["model_within_2x"] is True, (
    "fitted ckpt write model must predict the interior point within 2x")
from repro.core import resource_model as rm
from repro.core.platform import TPU_V5E
from repro.configs import get_arch
m = rm.ModelShape.from_arch(get_arch("granite-moe-3b-a800m"))
t = rm.TrainSetup(b=256, s=4096, PP=4, EP=4, DP=16, zero="world")
e = rm.estimate(m, t, TPU_V5E)
assert e.t_ckpt > 0 and e.ckpt_every_steps >= 1
assert 0.0 < e.goodput_factor <= 1.0 and e.mfu_effective <= e.mfu
print(f"robustness gate ok: {len(rec['recovery'])} drills recovered, "
      f"write model within 2x, Young-Daly ckpt@{e.ckpt_every_steps} steps "
      f"goodput={e.goodput_factor:.4f}")
PY

# Migration acceptance gate on the committed bench: the rebalanced run must
# recover >= 50% of the skew-induced modeled step-time loss (net of the
# Table-IV transfer costs), and hot-expert replication must land below the
# swap-only floor max(load_e)/fair_share — the blind spot the replication
# planner exists to close.  Replication numerics parity itself is pinned by
# tests/test_multidevice.py::test_replication_is_function_preserving.
python - <<'PY'
import json
rec = json.load(open("BENCH_migration.json"))
s = rec["summary"]
assert s["recovery_ge_half"] is True and s["modeled_recovery_frac"] >= 0.5, (
    f"rebalanced run must recover >= 50% of the modeled skew loss "
    f"(got {s['modeled_recovery_frac']:.2f}) -- regenerate the bench")
assert s["replication_beats_swap_floor"] is True, (
    "replication must beat the swap-only imbalance floor")
assert s["rebalance_beats_static"] is True
m = rec["modeled"]
print(f"migration gate ok: recovery={s['modeled_recovery_frac']:.2f}, "
      f"imb floor {m['swap_floor']:.2f} -> "
      f"{rec['modes']['replicated']['final_imbalance']:.2f} with replicas")
PY

# Observability acceptance gate on the committed bench: telemetry overhead
# (sinks on: ring + JSONL) stays within 2% of the uninstrumented step time,
# and the drift report covers every required phase (step, a2a, ckpt,
# decode) with a finite measured/modeled ratio.
python - <<'PY'
import json
rec = json.load(open("BENCH_observability.json"))
s = rec["summary"]
budget = rec["meta"]["overhead_budget_frac"]
assert s["overhead_within_budget"] is True and s["overhead_frac"] <= budget, (
    f"telemetry overhead {s['overhead_frac']:.4f} exceeds the "
    f"{budget:.0%} step-time budget -- regenerate the bench")
assert s["all_required_ratios_finite"] is True and s["phases_covered"] >= 4, (
    f"drift report must cover step/a2a/ckpt/decode with finite ratios "
    f"(got {s['covered']}) -- regenerate the bench")
print(f"obs gate ok: overhead {s['overhead_frac']*100:.2f}% <= "
      f"{budget:.0%}, drift phases {s['covered']}")
PY

exec python -m pytest -x -q "$@"
