"""Expert-migration demo (paper §VI): train a small MoE WITHOUT an aux
load-balancing loss so routing skews (the paper's expert-collapse setting),
watch group-level imbalance grow, and let the Alg-2 controller migrate
experts to re-balance devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/expert_migration.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import training
from repro.configs import get_arch
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.runtime import Trainer, TrainerConfig
from repro.sharding import host_mesh, make_plan, single_device_plan


def main():
    arch = get_arch("granite-moe-3b-a800m").reduced()
    # No aux loss -> the router is free to collapse (paper Fig 9 regime).
    arch = arch.replace(
        moe=dataclasses.replace(arch.moe, aux_loss_coef=0.0, top_k=1)
    )
    n = len(jax.devices())
    if n >= 4:
        mesh = host_mesh((1, 4), ("data", "model"))
        plan = make_plan(mesh, arch)
    else:
        plan = single_device_plan(arch)
    print(f"devices={plan.num_devices} ep={plan.ep} "
          f"(experts per group: {arch.moe.num_experts // max(plan.ep,1)})")

    lm = LanguageModel(arch, plan)
    opt = OptimizerConfig(lr=1e-3)
    with plan.mesh:
        state = training.init_state(lm, jax.random.PRNGKey(0), opt)
        data = SyntheticTokens(arch.vocab_size, 8, 64)
        trainer = Trainer(
            lm, opt,
            TrainerConfig(
                total_steps=60,
                migrate_every=10,
                migrate_threshold=1.05,
                log_every=10,
            ),
        )
        out = trainer.fit(state, data)
        stats = trainer.load_stats
        assign = np.concatenate([
            np.asarray(out["state"]["params"]["blocks"][0]["ffn"]["assignment"])
        ])
        print(f"\nmigration events: {len(out['migrations'])}")
        for m in out["migrations"]:
            print(f"  step {m['step']}: imbalance {m['imbalance']:.2f} -> "
                  f"{m['swaps']} swaps ({m['seconds']*1e3:.0f} ms)")
        if plan.ep > 1:
            print(f"post-migration imbalance: "
                  f"{stats.imbalance(assign, plan.ep):.3f} (1.0 = perfect)")


if __name__ == "__main__":
    main()
