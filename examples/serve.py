"""Serving example: batched prefill + greedy decode with KV/SSM caches.

    PYTHONPATH=src python examples/serve.py --arch jamba-1.5-large-398b \
        --prompt-len 64 --gen 32
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.model import LanguageModel, init_params
from repro.sharding import single_device_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    arch = get_arch(args.arch).reduced()
    plan = single_device_plan(arch)
    lm = LanguageModel(arch, plan)
    total_len = args.prompt_len + args.gen

    with plan.mesh:
        params = init_params(arch, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            arch.vocab_size,
        )
        prefill = jax.jit(lm.prefill)
        decode = jax.jit(lm.decode_step)

        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": prompt})
        # grow attention caches to the full generation length
        def grow(c):
            if "k" in c:
                pad = total_len - c["k"].shape[2]
                return {
                    k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                    for k, v in c.items()
                }
            return c

        cache = tuple(grow(c) for c in cache)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
              f"{t_prefill*1e3:.0f} ms")

        toks = jnp.argmax(logits, -1)[:, None]
        out = [toks]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            logits, cache = decode(
                params, cache, {"tokens": toks},
                jnp.int32(args.prompt_len + i),
            )
            toks = jnp.argmax(logits, -1)[:, None]
            out.append(toks)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        gen = np.concatenate([np.asarray(t) for t in out], axis=1)
        print(f"decode: {args.gen-1} steps in {dt*1e3:.0f} ms "
              f"({dt/(args.gen-1)*1e3:.1f} ms/token)")
        print("generated token ids (first row):", gen[0][:16], "...")


if __name__ == "__main__":
    main()
