"""HALO hierarchical all-to-all demo (paper §V).

Runs flat vs HALO a2a on 8 XLA host devices, verifies bit-equality, and
prints the analytic Frontier-topology speedups that reproduce Fig 8.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/halo_demo.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import halo
from repro.core.comm_model import A2ACase, speedup
from repro.core.platform import FRONTIER, TPU_V5E
from repro.sharding import MeshPlan, host_mesh


def main():
    n = len(jax.devices())
    print(f"{n} devices")
    if n >= 8:
        mesh = host_mesh((1, 8, 1), ("data", "ep", "tp"))
        plan = MeshPlan(mesh=mesh, ep=8, tp=1, dp_axes=("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 16, 32))

        from repro import compat

        def run(fn):
            return jax.jit(compat.shard_map(
                fn, mesh=mesh, in_specs=P("ep", None, None),
                out_specs=P("ep", None, None), check_vma=False,
            ))(x)

        flat = run(halo.flat_all_to_all)
        for g1 in (2, 4):
            h = run(lambda xl, g=g1: halo.hierarchical_all_to_all(xl, plan, g1=g))
            ok = np.allclose(np.asarray(flat), np.asarray(h))
            print(f"HALO(g1={g1}) == flat: {ok}")
    else:
        print("(run with XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "for the live equality check)")

    print("\nFig 8 reproduction — modeled HALO speedup on Frontier "
          "(4 MiB rows):")
    for nodes in (2, 4, 8, 16, 32, 64):
        case = A2ACase(nodes * FRONTIER.chips_per_node, 4 * 2**20)
        print(f"  {nodes:3d} nodes: {speedup(case, FRONTIER):5.2f}x")
    print("\nTPU analogue — inter-pod EP group (DCI slow axis):")
    for pods in (1, 2, 4):
        case = A2ACase(pods * 256, 2**20)
        print(f"  {pods} pod(s): {speedup(case, TPU_V5E):5.2f}x")


if __name__ == "__main__":
    main()
