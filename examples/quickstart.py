"""Quickstart: train a ~100M-param fine-grained MoE end to end on CPU.

    PYTHONPATH=src python examples/quickstart.py --steps 200

Exercises the full production path: planner report -> sharded train step ->
fault-tolerant trainer (checkpointing + expert migration + straggler
monitor) -> resume.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import training
from repro.configs.base import ArchConfig, MoECfg
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.runtime import Trainer, TrainerConfig
from repro.sharding import single_device_plan

QUICKSTART_100M = ArchConfig(
    name="quickstart-moe-100m",
    family="moe",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=0,
    vocab_size=32000,
    block_pattern=(("attn", "moe"),),
    moe=MoECfg(num_experts=4, top_k=2, d_ff=1024),
    tie_embeddings=True,
    source="quickstart",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/quickstart_ckpt")
    args = ap.parse_args()

    arch = QUICKSTART_100M
    print(f"model: {arch.name} — {arch.total_params()/1e6:.0f}M params "
          f"({arch.active_params()/1e6:.0f}M active)")

    plan = single_device_plan(arch)
    lm = LanguageModel(arch, plan)
    opt = OptimizerConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    with plan.mesh:
        state = training.init_state(lm, jax.random.PRNGKey(0), opt)
        data = SyntheticTokens(arch.vocab_size, args.batch, args.seq)
        trainer = Trainer(
            lm, opt,
            TrainerConfig(
                total_steps=args.steps,
                checkpoint_dir=args.ckpt_dir,
                checkpoint_every=100,
                migrate_every=50,
                log_every=20,
            ),
        )
        out = trainer.fit(state, data)
    print(f"final loss: {float(out['metrics']['loss']):.4f} "
          f"(migrations: {len(out['migrations'])}, "
          f"stragglers flagged: {len(out['stragglers'])})")
    print(f"mean step time: {np.mean(trainer.step_times[1:])*1e3:.0f} ms")


if __name__ == "__main__":
    main()
