"""Piper strategy search (the paper's §III-C/IV-C workflow): given a model
and a platform, enumerate memory-feasible (PP, EP, DP, policy) strategies
and rank them by estimated MFU.

    PYTHONPATH=src python examples/plan_search.py --arch grok-1-314b \
        --platform tpu-v5e --chips 256
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch, list_archs
from repro.core import planner
from repro.core.platform import PLATFORMS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="piper-super-545b")
    ap.add_argument("--platform", default="frontier-mi250x",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--chips", type=int, default=512)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--zero", default="dp", choices=["none", "dp", "world"])
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    platform = PLATFORMS[args.platform]
    print(f"{arch.name}: {arch.total_params()/1e9:.0f}B total / "
          f"{arch.active_params()/1e9:.0f}B active")
    print(f"platform: {platform.name} x{args.chips} chips "
          f"(HBM {platform.hbm_bytes/1e9:.0f}GB, fast domain "
          f"{platform.fast_domain})")

    strategies = planner.valid_strategies(
        arch, platform, args.chips, batch=args.batch, seq=args.seq,
        zero=args.zero,
    )
    print(f"{len(strategies)} feasible strategies (Eq 7-11); top "
          f"{args.top} by estimated MFU (Eq 12):\n")
    ranked = planner.rank_strategies(strategies)
    for s in ranked[: args.top]:
        print("  " + s.describe())
    if ranked:
        best = ranked[0]
        print(f"\nchosen: PP={best.PP} EP={best.EP} DP={best.DP} "
              f"schedule={best.schedule} vstages={best.vstages} "
              f"dispatch={best.dispatch} "
              f"(executor binds the schedule via MeshPlan.schedule/"
              f"MeshPlan.vstages and the dispatch via MoECfg.dispatch)")
    else:
        print("  NONE — increase chips, enable ZeRO (--zero world), or "
              "reduce batch.")


if __name__ == "__main__":
    main()
